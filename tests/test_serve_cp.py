"""Context-parallel chunked prefill vs the dense prefill path (world 4,
dp=2 x tp=2, subprocess — the main pytest process keeps 1 device).

Acceptance pins:
  * cp_attend="dense": the CP program's paged pools AND last-valid-token
    logits are BIT-EXACT vs the dense single-stream program, chunk by
    chunk (including a partial final chunk), under both zigzag and
    contiguous placements;
  * cp_attend="ring" (the balanced ring_attention + pool-prefix merge):
    pools stay bit-exact (the scatter-by-table write is attend-agnostic),
    logits agree to float tolerance, and end-to-end world-4 paged GREEDY
    TOKENS are unchanged vs the dense engine — for the whole-engine run
    at batch 4 with forced slot churn as well.
"""
import textwrap

import pytest

from conftest import run_devices

EXACT_SCRIPT = textwrap.dedent("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import ARCHS, reduced
    from repro.configs.base import ParallelConfig
    from repro.launch.mesh import make_mesh
    from repro.launch.serve import build_paged_engine
    from repro.ops.policy import OverlapPolicy
    from repro.serve import Request, ServeConfig

    DP, TP = 2, 2
    cfg = reduced(ARCHS["granite-3-2b"])
    pcfg = ParallelConfig(dp=DP, tp=TP, fsdp=True,
                          param_dtype="float32", compute_dtype="float32")
    mesh = make_mesh(DP, TP)
    # batch=1 < dp world -> the dense engine also runs ONE replicated
    # stream (dp_shards=1): its pools are directly comparable
    scfg = ServeConfig(batch=1, max_len=32, page_size=8, chunk=8,
                       token_budget=32)

    dense = build_paged_engine(cfg, pcfg, scfg, mesh)
    cp_d = build_paged_engine(cfg, pcfg, scfg, mesh, prefill_cp=True,
                              cp_attend="dense", cp_placement="zigzag")
    cp_dc = build_paged_engine(cfg, pcfg, scfg, mesh, prefill_cp=True,
                               cp_attend="dense", cp_placement="contiguous")
    # the ring-attend engine resolves the chunk-internal attention
    # through the placement-aware ring_fold transport (prefill policy
    # mode=ring) — its projections then ride a different collective
    # schedule, so its pools are tolerance-compared, not bitwise
    cp_r = build_paged_engine(
        cfg, pcfg, scfg, mesh, prefill_cp=True, cp_attend="ring",
        cp_placement="zigzag",
        prefill_policy=OverlapPolicy(mode="ring", backend="graph"))
    assert cp_d.prefill_cp and "prefill:ring_attention" in cp_d.overlap_modes()
    assert "prefill:ring_attention" not in dense.overlap_modes()

    def leaves(t):
        return [np.asarray(x) for x in jax.tree.leaves(t)]

    for a, b in zip(leaves(dense.params), leaves(cp_d.params)):
        assert np.array_equal(a, b)  # same seed -> identical params

    def zero_pools(eng):
        return jax.tree.map(lambda x: jnp.zeros(x.shape, x.dtype), eng.pools)

    # drive the raw prefill programs chunk by chunk: full chunk at
    # start=0, then a PARTIAL chunk (n_valid=5 < C) at start=8
    rng = np.random.RandomState(0)
    table = np.arange(1, dense.kv.pages_per_slot + 1,
                      dtype=np.int32)[None, :]          # pages 1..P
    toks = [rng.randint(1, cfg.vocab_size, size=(1, 8)).astype(np.int32)
            for _ in range(2)]
    chunks = [(np.int32([0]), np.int32([8])), (np.int32([8]), np.int32([5]))]

    def run_chunks(eng):
        pools = zero_pools(eng)
        outs = []
        for (start, nv), tk in zip(chunks, toks):
            logits, pools = eng.prefill_fn(eng.params, pools, table,
                                           start, nv, tk)
            outs.append(np.asarray(logits))
        return outs, [np.asarray(x) for x in jax.tree.leaves(pools)]

    log_dense, pool_dense = run_chunks(dense)
    for name, eng in (("zigzag", cp_d), ("contiguous", cp_dc)):
        log_cp, pool_cp = run_chunks(eng)
        for a, b in zip(log_dense, log_cp):
            assert np.array_equal(a, b), ("cp/dense logits not bit-exact",
                                          name)
        for a, b in zip(pool_dense, pool_cp):
            assert np.array_equal(a, b), ("cp/dense pools not bit-exact",
                                          name)
    log_ring, pool_ring = run_chunks(cp_r)
    for a, b in zip(pool_dense, pool_ring):
        # page 0 is the scratch page: padding rows park garbage there and
        # the two attend modes produce DIFFERENT garbage — compare the
        # real pages only (pool leaves are (n_layers, pages, ...))
        assert np.allclose(a[:, 1:], b[:, 1:], atol=1e-5), \
            "ring-attend pools drifted"
    for a, b in zip(log_dense, log_ring):
        err = np.abs(a - b).max()
        assert err < 1e-3, ("ring-attend logits drifted", err)
        assert a.argmax() == b.argmax()

    # whole-engine greedy generations (multi-chunk prompt incl. a
    # partial last chunk) are identical dense vs CP
    def probe(eng, prompt, n=5):
        r = Request(prompt=list(prompt), max_new_tokens=n)
        eng.add(r)
        assert eng.run(max_steps=500) == []
        return list(r.out_tokens)

    prompts = [[11, 7, 23, 4, 19, 3], list(range(2, 15))]  # 6 and 13 toks
    for p in prompts:
        want = probe(dense, p)
        assert len(want) == 5
        assert probe(cp_d, p) == want, ("cp-dense greedy tokens", p)
        assert probe(cp_r, p) == want, ("cp-ring greedy tokens", p)
    for a, b in zip(leaves(dense.pools), leaves(cp_d.pools)):
        assert np.array_equal(a, b)  # end-state pools still bit-equal
    print("OK")
""")


CHURN_SCRIPT = textwrap.dedent("""
    from repro.configs import ARCHS, reduced
    from repro.configs.base import ParallelConfig
    from repro.launch.mesh import make_mesh
    from repro.launch.serve import build_paged_engine
    from repro.serve import Request, ServeConfig

    DP, TP = 2, 2
    cfg = reduced(ARCHS["granite-3-2b"])
    pcfg = ParallelConfig(dp=DP, tp=TP, fsdp=True,
                          param_dtype="float32", compute_dtype="float32")
    mesh = make_mesh(DP, TP)
    scfg = ServeConfig(batch=4, max_len=32, page_size=8, chunk=8,
                       token_budget=32)

    # batch=4 >= dp world: dense prefill runs one stream PER data shard
    # (dp_shards=2) while CP runs one whole-mesh stream (dp_shards=1) —
    # greedy tokens must not depend on the prefill decomposition
    dense = build_paged_engine(cfg, pcfg, scfg, mesh)
    cp = build_paged_engine(cfg, pcfg, scfg, mesh, prefill_cp=True)
    assert dense.dp_shards == 2 and cp.dp_shards == 1

    def churn(eng):
        reqs = [Request(prompt=[9, 8, 7, 6, 5, (i % 3) + 1, 2 + i],
                        max_new_tokens=4) for i in range(5)]
        for r in reqs:   # 5 requests on 4 slots -> forced slot reuse
            eng.add(r)
        assert eng.run(max_steps=500) == []
        return [list(r.out_tokens) for r in reqs]

    a = churn(dense)
    b = churn(cp)
    assert a == b, ("world-4 greedy tokens changed under cp prefill", a, b)
    assert all(len(t) == 4 for t in a)
    print("OK")
""")


def test_cp_prefill_bit_exact_world4():
    out = run_devices(EXACT_SCRIPT, devices=4, timeout=1200)
    assert "OK" in out


def test_cp_prefill_greedy_unchanged_world4():
    out = run_devices(CHURN_SCRIPT, devices=4, timeout=1200)
    assert "OK" in out
