"""Roofline analyzer: HLO collective parsing, loop-trip handling, terms."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.launch import roofline


def test_parse_collectives_synthetic():
    hlo = """
  %all_gather.3 = f32[64,32]{1,0} all-gather(%x), channel_id=1, replica_groups={{0,1,2,3}}, dimensions={0}
  %reduce_scatter.7 = f32[16,32]{1,0} reduce-scatter(%y), channel_id=1, replica_groups={{0,1,2,3}}, dimensions={0}
  %ppermute.3 = f32[16,32]{1,0} collective-permute(%z), channel_id=1, source_target_pairs={{0,1},{1,2}}
  %ar = bf16[128]{0} all-reduce(%w), replica_groups={{0,1}}, to_apply=%sum
  %reduce_scatter.1 = f32[] parameter(0)
"""
    st = roofline.parse_collectives(hlo)
    ag = 64 * 32 * 4 * 3 / 4  # out_bytes * (W-1)/W
    rs = 16 * 32 * 4 * 4 * 3 / 4  # out * W * (W-1)/W
    cp = 16 * 32 * 4
    ar = 2 * 128 * 2 * 1 / 2
    assert st.op_counts == {"all-gather": 1, "reduce-scatter": 1,
                            "collective-permute": 1, "all-reduce": 1}
    np.testing.assert_allclose(st.wire_bytes, ag + rs + cp + ar)


def test_parse_collectives_loop_trips():
    hlo = ('  %p = f32[16,32]{1,0} collective-permute(%z), channel_id=1, '
           'source_target_pairs={{0,1}}, metadata={op_name="jit(f)/while/body/x"}\n')
    st = roofline.parse_collectives(hlo, loop_trips=7)
    assert st.op_counts["collective-permute"] == 7
    np.testing.assert_allclose(st.wire_bytes, 7 * 16 * 32 * 4)


def test_cost_analysis_does_not_multiply_loops():
    """Documents the behaviour analyze() compensates for: XLA's
    cost_analysis reports ONE iteration of a while loop."""
    def f(x, w):
        def body(h, wl):
            return jnp.dot(h, wl, preferred_element_type=jnp.float32), None
        h, _ = lax.scan(body, x, w)
        return h

    flops = {}
    for L in (1, 4):
        c = jax.jit(f).lower(
            jax.ShapeDtypeStruct((32, 32), jnp.float32),
            jax.ShapeDtypeStruct((L, 32, 32), jnp.float32),
        ).compile()
        flops[L] = roofline.normalize_cost(c.cost_analysis())["flops"]
    assert abs(flops[1] - flops[4]) / flops[1] < 0.01


def test_analyze_terms_and_dominance():
    class Mem:
        argument_size_in_bytes = 1 << 30
        output_size_in_bytes = 1 << 28
        temp_size_in_bytes = 1 << 29
        alias_size_in_bytes = 1 << 28

    rep = roofline.analyze(
        arch="x", shape_name="train_4k", mesh_desc="16x16", chips=256,
        cost={"flops": 1e12, "bytes accessed": 1e9},
        memory_stats=Mem(),
        hlo_text="", loop_trips=10, model_flops_total=10e12 * 256 * 0.5,
    )
    assert rep.t_compute == pytest.approx(1e13 / 197e12)
    assert rep.t_memory == pytest.approx(1e10 / 819e9)
    assert rep.t_collective == 0.0
    assert rep.dominant == "compute"
    assert rep.useful_flops_ratio == pytest.approx(0.5)
    assert rep.fits_hbm


def test_parse_real_lowering():
    """End-to-end: the parser finds the collectives of a real shard_map
    program (single-device axes still emit degenerate collectives or none —
    just assert no crash and sane structure)."""
    mesh = jax.make_mesh((1,), ("x",), axis_types=(jax.sharding.AxisType.Auto,))
    f = jax.jit(jax.shard_map(lambda x: lax.psum(x, "x"), mesh=mesh,
                              in_specs=P("x"), out_specs=P(), check_vma=False))
    txt = f.lower(jax.ShapeDtypeStruct((4, 4), jnp.float32)).compile().as_text()
    st = roofline.parse_collectives(txt)
    assert st.wire_bytes >= 0.0


def test_cpu_bf16_artifact_parser():
    hlo = ("  %wrapped_convert.9 = f32[61,22020096]{1,0} fusion(%param.84), "
           "kind=kLoop, calls=%c\n"
           "  %other = f32[4,4]{1,0} fusion(%notparam), kind=kLoop\n")
    got = roofline.cpu_bf16_artifact_bytes(hlo)
    assert got == 61 * 22020096 * 4
