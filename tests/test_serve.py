"""Serving engine tests (single device, tiny model)."""
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS, reduced
from repro.configs.base import ParallelConfig
from repro.models import build_model
from repro.serve.engine import Engine, Request

PCFG = ParallelConfig(dp=1, tp=1, fsdp=False, compute_dtype="float32",
                      param_dtype="float32", overlap_mode="none")


def _build(one_device_mesh, batch=2, s_max=32):
    cfg = reduced(ARCHS["granite-3-2b"])
    model = build_model(cfg, PCFG)
    params, pspecs = model.init(jax.random.PRNGKey(0), jnp.float32)
    caches = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                          model.cache_shapes(batch, s_max, jnp.float32))
    cache_specs = jax.tree.map(lambda x: P(*([None] * x.ndim)), caches)
    step = jax.jit(jax.shard_map(
        lambda p, c, n, t: model.decode_step_local(p, c, n, t),
        mesh=one_device_mesh,
        in_specs=(pspecs, cache_specs, None, P(None, None)),
        out_specs=(P(None, None), cache_specs), check_vma=False))
    return cfg, params, caches, step


def test_engine_completes_requests(one_device_mesh):
    cfg, params, caches, step = _build(one_device_mesh)
    eng = Engine(step, params, caches, batch=2, max_len=32)
    for i in range(3):
        eng.add(Request(prompt=[1, 2, 3], max_new_tokens=4))
    leftover = eng.run(max_steps=30)
    assert leftover == []


def test_greedy_decoding_is_deterministic(one_device_mesh):
    cfg, params, caches0, step = _build(one_device_mesh)
    outs = []
    for _ in range(2):
        caches = jax.tree.map(jnp.copy, caches0)
        eng = Engine(step, params, caches, batch=2, max_len=32)
        r = Request(prompt=[5, 6, 7], max_new_tokens=5)
        eng.add(r)
        eng.run(max_steps=30)
        outs.append(tuple(r.out_tokens))
    assert outs[0] == outs[1]
    assert len(outs[0]) == 5


def test_prefill_with_cache_matches_decode_loop(one_device_mesh):
    """The batched prefill (one forward pass -> logits + KV caches) must
    agree with token-by-token decode ingestion, both for the prefill
    logits AND for the next decode step using the produced caches."""
    cfg = reduced(ARCHS["granite-3-2b"])
    model = build_model(cfg, PCFG)
    params, pspecs = model.init(jax.random.PRNGKey(0), jnp.float32)
    b, s, s_max = 2, 8, 32
    toks = np.random.RandomState(1).randint(1, cfg.vocab_size, (b, s + 1)).astype(np.int32)

    pre = jax.jit(jax.shard_map(
        lambda p, t: model.prefill_with_cache_local(p, t, s_max, None),
        mesh=one_device_mesh, in_specs=(pspecs, P(None, None)),
        out_specs=(P(None, None), {"attn": {"k": P(*([None] * 5)),
                                            "v": P(*([None] * 5))}}),
        check_vma=False))
    logits_pre, caches_pre = pre(params, jnp.asarray(toks[:, :s]))

    caches = jax.tree.map(lambda sh: jnp.zeros(sh.shape, sh.dtype),
                          model.cache_shapes(b, s_max, jnp.float32))
    cache_specs = jax.tree.map(lambda x: P(*([None] * x.ndim)), caches)
    step = jax.jit(jax.shard_map(
        lambda p, c, n, t: model.decode_step_local(p, c, n, t),
        mesh=one_device_mesh,
        in_specs=(pspecs, cache_specs, None, P(None, None)),
        out_specs=(P(None, None), cache_specs), check_vma=False))
    logits_loop = None
    for i in range(s):
        logits_loop, caches = step(params, caches, jnp.int32(i),
                                   jnp.asarray(toks[:, i:i + 1]))
    np.testing.assert_allclose(np.asarray(logits_pre), np.asarray(logits_loop),
                               atol=2e-3, rtol=2e-3)
    # continue one decode step from BOTH cache states -> same logits
    nxt = jnp.asarray(toks[:, s:s + 1])
    l1, _ = step(params, caches_pre, jnp.int32(s), nxt)
    l2, _ = step(params, caches, jnp.int32(s), nxt)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=2e-3, rtol=2e-3)


def test_decode_matches_prefill_logits(one_device_mesh):
    """Feeding tokens one-by-one through the decode step must produce the
    same last-token logits as the full prefill forward."""
    cfg = reduced(ARCHS["granite-3-2b"])
    model = build_model(cfg, PCFG)
    params, pspecs = model.init(jax.random.PRNGKey(0), jnp.float32)
    b, s = 1, 8
    toks = np.random.RandomState(0).randint(1, cfg.vocab_size, (b, s)).astype(np.int32)

    pre = jax.jit(jax.shard_map(
        lambda p, t: model.prefill_logits_local(p, t, None),
        mesh=one_device_mesh, in_specs=(pspecs, P(None, None)),
        out_specs=P(None, None), check_vma=False))
    want = np.asarray(pre(params, jnp.asarray(toks)))

    caches = jax.tree.map(lambda sh: jnp.zeros(sh.shape, sh.dtype),
                          model.cache_shapes(b, 32, jnp.float32))
    cache_specs = jax.tree.map(lambda x: P(*([None] * x.ndim)), caches)
    step = jax.jit(jax.shard_map(
        lambda p, c, n, t: model.decode_step_local(p, c, n, t),
        mesh=one_device_mesh,
        in_specs=(pspecs, cache_specs, None, P(None, None)),
        out_specs=(P(None, None), cache_specs), check_vma=False))
    logits = None
    for i in range(s):
        logits, caches = step(params, caches, jnp.int32(i), jnp.asarray(toks[:, i:i+1]))
    np.testing.assert_allclose(np.asarray(logits), want, atol=2e-3, rtol=2e-3)


def test_engine_metrics_counters(one_device_mesh):
    cfg, params, caches, step = _build(one_device_mesh)
    eng = Engine(step, params, caches, batch=2, max_len=32)
    for _ in range(3):  # 3 requests on 2 slots -> one queues
        eng.add(Request(prompt=[1, 2, 3], max_new_tokens=4))
    leftover = eng.run(max_steps=30)
    assert leftover == []
    m = eng.metrics()
    assert m.requests_completed == 3
    assert m.tokens_generated == 12           # 3 requests x 4 tokens
    assert m.steps > 0
    assert m.ttft_mean_s > 0.0
    assert m.ttft_max_s >= m.ttft_mean_s
    assert m.tpot_mean_s > 0.0
    assert m.queue_depth_max >= 1             # the third request queued
    assert 0.0 < m.slot_occupancy_mean <= 1.0
    assert "Metrics(" in str(m)


def test_overlap_modes_report_wire_dtype(one_device_mesh):
    """Serve provenance carries the resolved wire dtype (PR-6 wire axis):
    always-explicit, f32 default and per-op overrides both visible."""
    from repro.ops.policy import OverlapPolicy

    cfg, params, caches, step = _build(one_device_mesh)
    pcfg = ParallelConfig(dp=1, tp=1, fsdp=False, compute_dtype="float32",
                          param_dtype="float32",
                          overlap=OverlapPolicy(
                              mode="ring", wires=(("ag_matmul", "int8"),)))
    eng = Engine(step, params, caches, batch=2, max_len=32, pcfg=pcfg)
    modes = eng.overlap_modes()
    assert set(modes) == set(Engine.OVERLAP_OPS)
    assert modes["ag_matmul"].endswith("/int8"), modes
    for op in ("matmul_rs", "a2a_ep", "flash_decode"):
        assert modes[op].endswith("/f32"), modes
    # mode/backend still lead the string
    for desc in modes.values():
        assert len(desc.split("/")) >= 3, desc


def test_overlap_modes_empty_without_pcfg(one_device_mesh):
    cfg, params, caches, step = _build(one_device_mesh)
    eng = Engine(step, params, caches, batch=2, max_len=32)
    assert eng.overlap_modes() == {}
