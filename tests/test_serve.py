"""Serving engine tests (single device, tiny model)."""
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS, reduced
from repro.configs.base import ParallelConfig
from repro.models import build_model
from repro.serve.engine import Engine, Request

PCFG = ParallelConfig(dp=1, tp=1, fsdp=False, compute_dtype="float32",
                      param_dtype="float32", overlap_mode="none")


def _build(one_device_mesh, batch=2, s_max=32):
    cfg = reduced(ARCHS["granite-3-2b"])
    model = build_model(cfg, PCFG)
    params, pspecs = model.init(jax.random.PRNGKey(0), jnp.float32)
    caches = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                          model.cache_shapes(batch, s_max, jnp.float32))
    cache_specs = jax.tree.map(lambda x: P(*([None] * x.ndim)), caches)
    step = jax.jit(jax.shard_map(
        lambda p, c, n, t: model.decode_step_local(p, c, n, t),
        mesh=one_device_mesh,
        in_specs=(pspecs, cache_specs, None, P(None, None)),
        out_specs=(P(None, None), cache_specs), check_vma=False))
    return cfg, params, caches, step


def test_engine_completes_requests(one_device_mesh):
    cfg, params, caches, step = _build(one_device_mesh)
    eng = Engine(step, params, caches, batch=2, max_len=32)
    for i in range(3):
        eng.add(Request(prompt=[1, 2, 3], max_new_tokens=4))
    leftover = eng.run(max_steps=30)
    assert leftover == []


def test_greedy_decoding_is_deterministic(one_device_mesh):
    cfg, params, caches0, step = _build(one_device_mesh)
    outs = []
    for _ in range(2):
        caches = jax.tree.map(jnp.copy, caches0)
        eng = Engine(step, params, caches, batch=2, max_len=32)
        r = Request(prompt=[5, 6, 7], max_new_tokens=5)
        eng.add(r)
        eng.run(max_steps=30)
        outs.append(tuple(r.out_tokens))
    assert outs[0] == outs[1]
    assert len(outs[0]) == 5


def test_prefill_with_cache_matches_decode_loop(one_device_mesh):
    """The batched prefill (one forward pass -> logits + KV caches) must
    agree with token-by-token decode ingestion, both for the prefill
    logits AND for the next decode step using the produced caches."""
    cfg = reduced(ARCHS["granite-3-2b"])
    model = build_model(cfg, PCFG)
    params, pspecs = model.init(jax.random.PRNGKey(0), jnp.float32)
    b, s, s_max = 2, 8, 32
    toks = np.random.RandomState(1).randint(1, cfg.vocab_size, (b, s + 1)).astype(np.int32)

    pre = jax.jit(jax.shard_map(
        lambda p, t: model.prefill_with_cache_local(p, t, s_max, None),
        mesh=one_device_mesh, in_specs=(pspecs, P(None, None)),
        out_specs=(P(None, None), {"attn": {"k": P(*([None] * 5)),
                                            "v": P(*([None] * 5))}}),
        check_vma=False))
    logits_pre, caches_pre = pre(params, jnp.asarray(toks[:, :s]))

    caches = jax.tree.map(lambda sh: jnp.zeros(sh.shape, sh.dtype),
                          model.cache_shapes(b, s_max, jnp.float32))
    cache_specs = jax.tree.map(lambda x: P(*([None] * x.ndim)), caches)
    step = jax.jit(jax.shard_map(
        lambda p, c, n, t: model.decode_step_local(p, c, n, t),
        mesh=one_device_mesh,
        in_specs=(pspecs, cache_specs, None, P(None, None)),
        out_specs=(P(None, None), cache_specs), check_vma=False))
    logits_loop = None
    for i in range(s):
        logits_loop, caches = step(params, caches, jnp.int32(i),
                                   jnp.asarray(toks[:, i:i + 1]))
    np.testing.assert_allclose(np.asarray(logits_pre), np.asarray(logits_loop),
                               atol=2e-3, rtol=2e-3)
    # continue one decode step from BOTH cache states -> same logits
    nxt = jnp.asarray(toks[:, s:s + 1])
    l1, _ = step(params, caches_pre, jnp.int32(s), nxt)
    l2, _ = step(params, caches, jnp.int32(s), nxt)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=2e-3, rtol=2e-3)


def test_decode_matches_prefill_logits(one_device_mesh):
    """Feeding tokens one-by-one through the decode step must produce the
    same last-token logits as the full prefill forward."""
    cfg = reduced(ARCHS["granite-3-2b"])
    model = build_model(cfg, PCFG)
    params, pspecs = model.init(jax.random.PRNGKey(0), jnp.float32)
    b, s = 1, 8
    toks = np.random.RandomState(0).randint(1, cfg.vocab_size, (b, s)).astype(np.int32)

    pre = jax.jit(jax.shard_map(
        lambda p, t: model.prefill_logits_local(p, t, None),
        mesh=one_device_mesh, in_specs=(pspecs, P(None, None)),
        out_specs=P(None, None), check_vma=False))
    want = np.asarray(pre(params, jnp.asarray(toks)))

    caches = jax.tree.map(lambda sh: jnp.zeros(sh.shape, sh.dtype),
                          model.cache_shapes(b, 32, jnp.float32))
    cache_specs = jax.tree.map(lambda x: P(*([None] * x.ndim)), caches)
    step = jax.jit(jax.shard_map(
        lambda p, c, n, t: model.decode_step_local(p, c, n, t),
        mesh=one_device_mesh,
        in_specs=(pspecs, cache_specs, None, P(None, None)),
        out_specs=(P(None, None), cache_specs), check_vma=False))
    logits = None
    for i in range(s):
        logits, caches = step(params, caches, jnp.int32(i), jnp.asarray(toks[:, i:i+1]))
    np.testing.assert_allclose(np.asarray(logits), want, atol=2e-3, rtol=2e-3)


def test_engine_metrics_counters(one_device_mesh):
    cfg, params, caches, step = _build(one_device_mesh)
    eng = Engine(step, params, caches, batch=2, max_len=32)
    for _ in range(3):  # 3 requests on 2 slots -> one queues
        eng.add(Request(prompt=[1, 2, 3], max_new_tokens=4))
    leftover = eng.run(max_steps=30)
    assert leftover == []
    m = eng.metrics()
    assert m.requests_completed == 3
    assert m.tokens_generated == 12           # 3 requests x 4 tokens
    assert m.steps > 0
    assert m.ttft_mean_s > 0.0
    assert m.ttft_max_s >= m.ttft_mean_s
    assert m.tpot_mean_s > 0.0
    assert m.queue_depth_max >= 1             # the third request queued
    assert 0.0 < m.slot_occupancy_mean <= 1.0
    assert "Metrics(" in str(m)


def test_overlap_modes_report_wire_dtype(one_device_mesh):
    """Serve provenance carries the resolved wire dtype (PR-6 wire axis):
    always-explicit, f32 default and per-op overrides both visible."""
    from repro.ops.policy import OverlapPolicy

    cfg, params, caches, step = _build(one_device_mesh)
    pcfg = ParallelConfig(dp=1, tp=1, fsdp=False, compute_dtype="float32",
                          param_dtype="float32",
                          overlap=OverlapPolicy(
                              mode="ring", wires=(("ag_matmul", "int8"),)))
    eng = Engine(step, params, caches, batch=2, max_len=32, pcfg=pcfg)
    modes = eng.overlap_modes()
    assert set(modes) == set(Engine.OVERLAP_OPS)
    assert modes["ag_matmul"].endswith("/int8"), modes
    for op in ("matmul_rs", "a2a_ep", "flash_decode"):
        assert modes[op].endswith("/f32"), modes
    # mode/backend still lead the string
    for desc in modes.values():
        assert len(desc.split("/")) >= 3, desc


def test_overlap_modes_empty_without_pcfg(one_device_mesh):
    cfg, params, caches, step = _build(one_device_mesh)
    eng = Engine(step, params, caches, batch=2, max_len=32)
    assert eng.overlap_modes() == {}


# ---------------------------------------------------------------------------
# Metrics under contention (fake step fn + fake clock -> hand-computed)
# ---------------------------------------------------------------------------


class _FakeClock:
    """perf_counter stub: returns 0, 1, 2, ... — one tick per call."""

    def __init__(self):
        self.t = -1

    def __call__(self):
        self.t += 1
        return float(self.t)


def test_metrics_under_contention_hand_computed(monkeypatch):
    """3 requests on 2 slots, prompt 3 + 2 generated each, fake clock.

    Call order is deterministic: adds stamp t=0,1,2; each step stamps
    one tick (t=3..). A request takes 4 steps — the step feeding the
    last prompt token also yields the first generated token. Requests
    1+2 run steps 1-4 (now=3..6), request 3 queues through step 4 and
    runs steps 5-8 (now=7..10). Hand-computed:
      ttft r1 = 5-0, r2 = 5-1, r3 = 9-2  (queue wait INCLUDED)
      tpot    = 1 tick/token for all (excludes the first token)
      queue samples  [1]*4 + [0]*4   -> mean 0.5, max 1
      occupancy      [1.]*4 + [.5]*4 -> mean 0.75
    """
    import repro.serve.engine as engine_mod

    monkeypatch.setattr(engine_mod.time, "perf_counter", _FakeClock())
    step_fn = lambda p, c, n, t: (np.zeros((2, 16), np.float32), c)
    eng = Engine(step_fn, params=None, init_caches=None, batch=2, max_len=32)
    for _ in range(3):
        eng.add(Request(prompt=[1, 2, 3], max_new_tokens=2))
    assert eng.run(max_steps=50) == []
    m = eng.metrics()
    assert m.requests_completed == 3
    assert m.tokens_generated == 6
    assert m.steps == m.steps_decode == 8
    assert m.ttft_mean_s == (5 + 4 + 7) / 3
    assert m.ttft_max_s == 7.0            # r3's queue wait is in its TTFT
    assert m.tpot_mean_s == 1.0           # (t_done-t_first)/(n_out-1)
    assert m.queue_depth_mean == 0.5
    assert m.queue_depth_max == 1
    assert m.slot_occupancy_mean == 0.75


def test_truncation_flag_on_capacity(monkeypatch):
    """A request that hits max_len mid-generation finishes with an
    explicit truncated flag (no silent stranding) and is counted."""
    import repro.serve.engine as engine_mod

    monkeypatch.setattr(engine_mod.time, "perf_counter", _FakeClock())
    step_fn = lambda p, c, n, t: (np.zeros((1, 16), np.float32), c)
    eng = Engine(step_fn, params=None, init_caches=None, batch=1, max_len=4)
    req = Request(prompt=[1, 2, 3], max_new_tokens=8)
    eng.add(req)
    assert eng.run(max_steps=20) == []    # finishes despite the tight cache
    assert req.done and req.truncated
    assert len(req.out_tokens) == 2       # positions 3,4 then capacity
    m = eng.metrics()
    assert m.requests_truncated == 1
    assert m.requests_completed == 1


def test_untruncated_requests_keep_flag_clear(monkeypatch):
    import repro.serve.engine as engine_mod

    monkeypatch.setattr(engine_mod.time, "perf_counter", _FakeClock())
    step_fn = lambda p, c, n, t: (np.zeros((1, 16), np.float32), c)
    eng = Engine(step_fn, params=None, init_caches=None, batch=1, max_len=32)
    req = Request(prompt=[1, 2, 3], max_new_tokens=4)
    eng.add(req)
    eng.run(max_steps=20)
    assert req.done and not req.truncated
    assert eng.metrics().requests_truncated == 0


# ---------------------------------------------------------------------------
# Slot-reuse isolation (the PR-8 regression): a reused slot must produce
# bit-identical tokens to a fresh engine — stale KV fully masked out.
# ---------------------------------------------------------------------------


def test_slot_reuse_matches_fresh_engine_tokenwise(one_device_mesh):
    cfg, params, caches0, step = _build(one_device_mesh)
    probe_prompt = [11, 7, 23, 4]

    reused = Engine(step, params, jax.tree.map(jnp.copy, caches0),
                    batch=2, max_len=32)
    for _ in range(3):  # churn: fill + free both slots first
        reused.add(Request(prompt=[9, 8, 7, 6, 5], max_new_tokens=6))
    assert reused.run(max_steps=60) == []
    probe_a = Request(prompt=list(probe_prompt), max_new_tokens=5)
    reused.add(probe_a)
    assert reused.run(max_steps=60) == []

    fresh = Engine(step, params, jax.tree.map(jnp.copy, caches0),
                   batch=2, max_len=32)
    probe_b = Request(prompt=list(probe_prompt), max_new_tokens=5)
    fresh.add(probe_b)
    assert fresh.run(max_steps=60) == []
    assert probe_a.out_tokens == probe_b.out_tokens  # bit-identical


def test_slot_reuse_matches_fresh_engine_paged(one_device_mesh):
    from repro.launch.serve import build_paged_engine
    from repro.serve import ServeConfig

    cfg = reduced(ARCHS["granite-3-2b"])
    scfg = ServeConfig(batch=2, max_len=32, page_size=8, chunk=4,
                       token_budget=8)
    probe_prompt = [11, 7, 23, 4, 19, 3]

    def probe_tokens(engine, churn: bool):
        if churn:
            for _ in range(3):
                engine.add(Request(prompt=[9, 8, 7, 6, 5], max_new_tokens=6))
            assert engine.run() == []
        probe = Request(prompt=list(probe_prompt), max_new_tokens=5)
        engine.add(probe)
        assert engine.run() == []
        return probe.out_tokens

    reused = build_paged_engine(cfg, PCFG, scfg, one_device_mesh)
    fresh = build_paged_engine(cfg, PCFG, scfg, one_device_mesh)
    assert probe_tokens(reused, churn=True) == probe_tokens(fresh, churn=False)


# ---------------------------------------------------------------------------
# Scheduler: deterministic planning + bounded-queue backpressure
# ---------------------------------------------------------------------------


def test_scheduler_plan_is_deterministic():
    from repro.serve import PagedKVCache, ServeConfig
    from repro.serve.scheduler import Scheduler

    scfg = ServeConfig(batch=4, max_len=16, page_size=8, chunk=4,
                       token_budget=6)
    kv = PagedKVCache(batch=4, max_len=16, page_size=8, dp_shards=2)
    sched = Scheduler(scfg, kv, dp_shards=2)
    for _ in range(3):
        sched.submit(Request(prompt=list(range(1, 7)), max_new_tokens=2))
    assert sched.admit() == [0, 1, 2]
    # one chunk per DP shard; slot 2's 4 tokens exceed the remaining
    # budget (6-4=2) so shard 1 waits this step
    assert sched.plan().prefill == [(0, 0, 4)]
    assert sched.note_chunk(0, 4) is False
    # next step: slot 0's 2-token tail + shard 1's first chunk both fit
    assert sched.plan().prefill == [(0, 4, 2), (2, 0, 4)]
    assert sched.note_chunk(0, 2) is True   # prompt done -> decode phase
    plan = sched.plan()
    assert plan.decode == [0]
    # decode consumed 1 budget token; slot 1's chunk (4) fits the
    # remaining 5, slot 2's tail (2) no longer does
    assert plan.prefill == [(1, 0, 4)]


def test_bounded_queue_backpressure():
    from repro.serve import PagedKVCache, ServeConfig
    from repro.serve.scheduler import Scheduler

    scfg = ServeConfig(batch=1, max_len=16, page_size=8, queue_cap=2)
    kv = PagedKVCache(batch=1, max_len=16, page_size=8)
    sched = Scheduler(scfg, kv)
    assert sched.submit(Request(prompt=[1]))
    assert sched.submit(Request(prompt=[2]))
    assert not sched.submit(Request(prompt=[3]))  # queue full
    assert sched.queue_depth() == 2
