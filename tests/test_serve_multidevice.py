"""Serving engines on multi-device meshes (subprocess — the main pytest
process keeps 1 device).

Two properties per world size:
  * slot-reuse isolation — a probe request decoded after the engine has
    filled and freed every slot (and, paged, every page) emits tokens
    bit-identical to the same probe on a fresh engine;
  * paged == tokenwise — the chunked-prefill + paged-decode path agrees
    with the legacy dense-cache token-by-token path on greedy tokens.

World 4 additionally splits the overlap policy per phase (prefill
bidir/graph, decode one_shot/graph) to exercise the two-program policy
resolution under dp=2, tp=2, fsdp.
"""
import textwrap

import pytest

from conftest import run_devices

SCRIPT = textwrap.dedent("""
    import dataclasses
    from repro.configs import ARCHS, reduced
    from repro.configs.base import ParallelConfig
    from repro.ops.policy import OverlapPolicy
    from repro.launch.mesh import make_mesh
    from repro.launch.serve import build_paged_engine, build_tokenwise_engine
    from repro.serve import Request, ServeConfig

    DP, TP, SPLIT = {dp}, {tp}, {split}
    cfg = reduced(ARCHS["granite-3-2b"])
    pcfg = ParallelConfig(dp=DP, tp=TP, fsdp=True,
                          param_dtype="float32", compute_dtype="float32")
    mesh = make_mesh(DP, TP)
    scfg = ServeConfig(batch=4, max_len=32, page_size=8, chunk=8,
                       token_budget=32)
    PROBE = [11, 7, 23, 4, 19, 3]

    def probe(engine):
        r = Request(prompt=list(PROBE), max_new_tokens=5)
        engine.add(r)
        assert engine.run(max_steps=500) == []
        return list(r.out_tokens)

    def churn(engine):
        for i in range(5):   # 5 requests on 4 slots -> forced slot reuse
            engine.add(Request(prompt=[9, 8, 7, 6, 5, (i % 3) + 1],
                               max_new_tokens=4))
        assert engine.run(max_steps=500) == []

    ppol = None
    if SPLIT:  # per-phase overlap: prefill bidir, decode one_shot
        ppol = OverlapPolicy(mode="bidir", backend="graph")
        pcfg = dataclasses.replace(
            pcfg, overlap=OverlapPolicy(mode="one_shot", backend="graph"))

    paged = build_paged_engine(cfg, pcfg, scfg, mesh, prefill_policy=ppol)
    a = probe(paged)           # fresh pools
    churn(paged)               # fill + free every slot and its pages
    b = probe(paged)           # probe rides reused slot + reused pages
    assert a == b, ("paged slot reuse leaked", a, b)
    assert len(a) == 5

    tok = build_tokenwise_engine(cfg, pcfg, scfg.batch, scfg.max_len, mesh)
    c = probe(tok)
    churn(tok)
    d = probe(tok)
    assert c == d, ("tokenwise slot reuse leaked", c, d)

    assert a == c, ("paged != tokenwise", a, c)
    print("OK", a)
""")


@pytest.mark.parametrize(
    "devices,dp,tp,split",
    [(2, 1, 2, False), (4, 2, 2, True), (8, 4, 2, False)],
    ids=["world2-tp2", "world4-dp2tp2-phase-split", "world8-dp4tp2"],
)
def test_slot_reuse_and_paged_parity(devices, dp, tp, split):
    out = run_devices(SCRIPT.format(dp=dp, tp=tp, split=split),
                      devices=devices, timeout=1200)
    assert "OK" in out
