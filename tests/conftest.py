"""Test harness config.

IMPORTANT: no XLA_FLAGS here — the main pytest process sees ONE CPU device
(smoke tests run on a (1,1) mesh). Multi-device tests spawn subprocesses
with --xla_force_host_platform_device_count=N via ``run_devices``.
"""
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


def run_devices(script: str, devices: int = 8, timeout: int = 900) -> str:
    """Run a python script in a subprocess with N virtual CPU devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-c", script],
        capture_output=True,
        text=True,
        env=env,
        timeout=timeout,
        cwd=REPO,
    )
    if proc.returncode != 0:
        raise AssertionError(
            f"subprocess failed (rc={proc.returncode})\n"
            f"--- stdout ---\n{proc.stdout[-4000:]}\n"
            f"--- stderr ---\n{proc.stderr[-4000:]}"
        )
    return proc.stdout


@pytest.fixture(scope="session")
def one_device_mesh():
    import jax

    return jax.make_mesh(
        (1, 1), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2,
    )
