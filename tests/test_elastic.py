"""Elastic restart end-to-end: checkpoint from a dp=4 mesh, reshard the
packed leaves to dp=2, and verify the dp=2 model computes the SAME loss —
the node-failure recovery path (4 hosts -> 2 hosts)."""
import textwrap

from conftest import run_devices

SCRIPT = textwrap.dedent("""
    import numpy as np
    import jax, jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.configs import ARCHS, reduced
    from repro.configs.base import ParallelConfig
    from repro.models import build_model
    from repro.train.checkpoint import _flatten, _unflatten_into, reshard_checkpoint

    cfg = reduced(ARCHS["granite-3-2b"])
    tokens = jnp.asarray(np.random.RandomState(0).randint(0, cfg.vocab_size, (4, 32)),
                         jnp.int32)

    def loss_on(dp, params=None):
        pcfg = ParallelConfig(dp=dp, tp=1, fsdp=True, overlap_mode="ring",
                              compute_dtype="float32", param_dtype="float32")
        mesh = jax.make_mesh((dp, 1), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,) * 2)
        model = build_model(cfg, pcfg)
        if params is None:
            params, pspecs = model.init(jax.random.PRNGKey(0), jnp.float32)
        else:
            _, pspecs = model.param_shapes(jnp.float32)
        f = jax.jit(jax.shard_map(
            lambda p, t, l: model.loss_local(p, t, l, None), mesh=mesh,
            in_specs=(pspecs, P("data", None), P("data", None)),
            out_specs=P(), check_vma=False))
        return float(f(params, tokens, tokens)), params, model

    loss4, params4, model4 = loss_on(4)

    # "checkpoint" -> flat numpy -> reshard dp=4 -> dp=2 -> restore
    flat = {k: np.asarray(v) for k, v in _flatten({"params": params4}).items()}
    spec_tree = {"params": {"top": model4.top_specs, "layers": model4.layer_specs}}
    flat_specs = _flatten(spec_tree)
    old = ParallelConfig(dp=4, tp=1)
    new = ParallelConfig(dp=2, tp=1)
    res = reshard_checkpoint(flat, flat_specs, old, new)
    params2 = _unflatten_into({"params": params4}, {k: jnp.asarray(v) for k, v in res.items()})["params"]
    # shapes must match the dp=2 packed layout
    pcfg2 = ParallelConfig(dp=2, tp=1, compute_dtype="float32", param_dtype="float32")
    from repro.models import build_model as bm
    shapes2, _ = bm(cfg, pcfg2).param_shapes(jnp.float32)
    for a, b in zip(jax.tree.leaves(params2), jax.tree.leaves(shapes2)):
        assert a.shape == b.shape, (a.shape, b.shape)

    loss2, _, _ = loss_on(2, params=params2)
    assert abs(loss2 - loss4) < 5e-4, (loss2, loss4)
    print("OK", loss4, loss2)
""")


def test_elastic_reshard_preserves_model():
    out = run_devices(SCRIPT, devices=4)
    assert "OK" in out
