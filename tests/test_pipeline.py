"""GPipe pipeline-parallel utility: pipelined == sequential, grads flow."""
import textwrap

from conftest import run_devices

SCRIPT = textwrap.dedent("""
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.dist.pipeline import gpipe, gpipe_last_stage_value

    S, M, MB, D = 4, 6, 2, 8
    mesh = jax.make_mesh((S,), ("stage",), axis_types=(jax.sharding.AxisType.Auto,))
    rng = np.random.RandomState(0)
    ws = jnp.asarray(rng.randn(S, D, D) / np.sqrt(D), jnp.float32)
    xs = jnp.asarray(rng.randn(M, MB, D), jnp.float32)

    def stage_fn(p, x):
        return jnp.tanh(x @ p["w"][0])

    def run(params, micro):
        outs = gpipe(stage_fn, params, micro, axis="stage")
        return gpipe_last_stage_value(outs, "stage")

    f = jax.jit(jax.shard_map(run, mesh=mesh,
        in_specs=({"w": P("stage", None, None)}, P(None, None, None)),
        out_specs=P(None, None, None), check_vma=False))
    got = np.asarray(f({"w": ws}, xs))

    want = np.asarray(xs)
    for s in range(S):
        want = np.tanh(want @ np.asarray(ws[s]))
    assert np.abs(got - want).max() < 1e-5, np.abs(got - want).max()

    # gradients flow through the pipeline (ppermute transposes)
    def loss(params, micro):
        return jnp.sum(jnp.square(run(params, micro)))
    g = jax.jit(jax.shard_map(jax.grad(loss), mesh=mesh,
        in_specs=({"w": P("stage", None, None)}, P(None, None, None)),
        out_specs={"w": P("stage", None, None)}, check_vma=False))({"w": ws}, xs)
    gn = np.asarray(g["w"])
    assert np.isfinite(gn).all() and np.abs(gn).max() > 0
    print("OK")
""")


def test_gpipe_matches_sequential_and_differentiates():
    out = run_devices(SCRIPT, devices=4)
    assert "OK" in out
