"""MoE dispatch/combine properties (single device)."""
import os
import sys
sys.path.insert(0, os.path.dirname(__file__))

import jax.numpy as jnp
import numpy as np

import proptest as pt
from repro.core import moe_overlap as mo

R = np.random.RandomState(0)


@pt.given(examples=12, t=pt.sampled_from([8, 16, 32]), e=pt.sampled_from([4, 8]),
          k=pt.sampled_from([1, 2, 4]))
def test_dispatch_combine_identity(t, e, k):
    """With no drops, combine(identity_expert(dispatch(x))) == x because
    the top-k weights renormalize to 1."""
    d = 16
    x = jnp.asarray(R.randn(t, d), jnp.float32)
    logits = jnp.asarray(R.randn(t, e), jnp.float32)
    disp, info = mo.topk_dispatch(x, logits, k, capacity=t * k)
    y = mo.topk_combine(disp, info)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x), atol=1e-5, rtol=1e-5)


@pt.given(examples=12, t=pt.sampled_from([16, 32]), e=pt.sampled_from([4, 8]))
def test_dispatch_respects_capacity(t, e):
    d, k, cap = 8, 2, 8
    x = jnp.asarray(R.randn(t, d), jnp.float32)
    logits = jnp.asarray(R.randn(t, e), jnp.float32)
    disp, info = mo.topk_dispatch(x, logits, k, capacity=cap)
    assert disp.shape == (e, cap, d)
    assert bool(jnp.all(info.position < cap))
    # weights of kept slots are positive, dropped slots zero, all finite
    assert bool(jnp.all(jnp.isfinite(info.weight)))
    assert bool(jnp.all(info.weight >= 0))


@pt.given(examples=10, t=pt.sampled_from([16, 32]), e=pt.sampled_from([4, 8]),
          k=pt.sampled_from([1, 2]))
def test_dispatch_slot_uniqueness(t, e, k):
    """No two kept token-slots map to the same (expert, position)."""
    d = 4
    x = jnp.asarray(R.randn(t, d), jnp.float32)
    logits = jnp.asarray(R.randn(t, e), jnp.float32)
    cap = t * k
    disp, info = mo.topk_dispatch(x, logits, k, cap)
    kept = np.asarray(info.weight).reshape(-1) > 0
    pairs = np.stack([np.asarray(info.expert).reshape(-1),
                      np.asarray(info.position).reshape(-1)], 1)[kept]
    assert len({tuple(p) for p in pairs}) == kept.sum()


def test_combine_weights_sum_to_one():
    t, e, k, d = 32, 8, 4, 8
    x = jnp.asarray(R.randn(t, d), jnp.float32)
    logits = jnp.asarray(R.randn(t, e), jnp.float32)
    _, info = mo.topk_dispatch(x, logits, k, capacity=t * k)
    np.testing.assert_allclose(np.asarray(info.weight.sum(-1)), 1.0, atol=1e-5)
