"""repro.ops authoring-API tests.

1. Declaring a toy op IN-TEST via ``OverlapOp`` auto-appears in the
   engine registry with derived graph + kernel lowerings and the derived
   dual-schedule backward; it passes graph-vs-kernel parity at worlds
   2/4/8 and round-trips grads bit-identically through the ONE shared
   custom_vjp (kernel forward keeps the graph dual as its backward).
2. ``ops.fuse``: the fused rs->ag boundary declaration
   (``matmul_rs_ag_matmul``) matches the composed unfused pair in values
   AND grads at worlds 2/4/8, on both backends, with grads bit-identical
   across backends (the backward recomputes on a fixed graph path).
3. ``OverlapPolicy``: single-point resolution (mode clamped by the
   registry, backend degraded off kernel-incapable pairs, chunk count
   picked by op kind), dict ergonomics, hw-aware degrade.
"""
import dataclasses
import textwrap

import pytest

from conftest import run_devices

TOY = textwrap.dedent("""
    import functools
    import jax, jax.numpy as jnp, numpy as np
    from jax import lax
    from jax.sharding import PartitionSpec as P
    from repro import ops
    from repro.core import overlap as ov

    W = __WORLD__
    mesh = jax.make_mesh((W,), ("tp",), axis_types=(jax.sharding.AxisType.Auto,))
    rng = np.random.RandomState(0)

    # ---- declare toy ops IN-TEST (nonlinear in the static operand) ----
    assert "toy_ag" not in ov.registry()
    toy_tile = lambda c, w: jnp.dot(c, jnp.tanh(w),
                                    preferred_element_type=jnp.float32)
    toy_ag = ops.declare(ops.OverlapOp(
        name="toy_ag", kind="ag", tile=toy_tile,
        transports=("ring", "bidir", "one_shot"),
        kernel_protocols=(("ring", "ring_ag"), ("bidir", "bidir_ring_ag"),
                          ("one_shot", "one_shot_ag")),
        transpose="matmul_rs", rowwise=True))
    toy_rs = ops.declare(ops.OverlapOp(
        name="toy_rs", kind="rs", tile=toy_tile,
        transports=("ring", "one_shot"),
        kernel_protocols=(("ring", "push_rs"), ("one_shot", "one_shot_rs")),
        transpose="toy_ag"))

    # auto-registration: spec with derived fwd/bwd/kernel_fwd appears
    spec = ov.get("toy_ag")
    assert spec.kind == "ag"
    assert spec.kernel_transports == ("ring", "bidir", "one_shot")
    assert spec.fwd is not None and spec.bwd is not None
    assert spec.kernel_fwd is not None
    # ...and is immediately visible to tuner candidate enumeration and
    # policy resolution, with no extra wiring
    assert ov.transports_for("toy_ag") == ("ring", "bidir", "one_shot")
    assert ov.backends_for("toy_rs") == ("graph", "kernel")
    pol = ops.OverlapPolicy(mode="ring", backend="kernel")
    assert pol.resolve("toy_ag").backend == "kernel"
    assert pol.resolve("toy_rs").backend == "kernel"
    assert pol.resolve("toy_ag", hw=None).mode == "ring"

    def sh(fn, in_specs, out_specs):
        return jax.jit(jax.shard_map(fn, mesh=mesh, in_specs=in_specs,
                                     out_specs=out_specs, check_vma=False))

    M, K, N = 4 * W, 8, 2 * W
    A = jnp.asarray(rng.randn(M, K), jnp.float32)
    Wt = jnp.asarray(rng.randn(K, N), jnp.float32)
    want = np.asarray(A) @ np.tanh(np.asarray(Wt))

    AG_SPECS = ((P("tp", None), P(None, "tp")), P(None, "tp"))
    # derived graph lowering matches the oracle on every transport
    for mode in ("none", "ring", "bidir", "one_shot"):
        f = sh(functools.partial(toy_ag, axis="tp", mode=mode,
                                 out_dtype=jnp.float32), *AG_SPECS)
        err = np.abs(np.asarray(f(A, Wt)) - want).max()
        assert err < 2e-4, ("toy_ag", mode, err)

    # graph-vs-kernel parity for every declared (transport, protocol)
    def run(op, specs, mode, backend, *xs):
        f = sh(functools.partial(op, axis="tp", mode=mode, backend=backend,
                                 out_dtype=jnp.float32), *specs)
        return np.asarray(f(*xs))

    for mode in ("ring", "bidir", "one_shot"):
        k = run(toy_ag, AG_SPECS, mode, "kernel", A, Wt)
        g = run(toy_ag, AG_SPECS, mode, "graph", A, Wt)
        assert np.abs(k - g).max() < 2e-4, ("toy_ag kernel", mode)

    RS_SPECS = ((P(None, "tp"), P("tp", None)), P("tp", None))
    A2 = jnp.asarray(rng.randn(M, 4 * W), jnp.float32)
    W2 = jnp.asarray(rng.randn(4 * W, N), jnp.float32)
    want2 = np.asarray(A2) @ np.tanh(np.asarray(W2))
    for mode in ("none", "ring", "one_shot"):
        g = run(toy_rs, RS_SPECS, mode, "graph", A2, W2)
        assert np.abs(g - want2).max() < 2e-4, ("toy_rs", mode)
    for mode in ("ring", "one_shot"):
        k = run(toy_rs, RS_SPECS, mode, "kernel", A2, W2)
        g = run(toy_rs, RS_SPECS, mode, "graph", A2, W2)
        assert np.abs(k - g).max() < 2e-4, ("toy_rs kernel", mode)

    # grads round-trip the SHARED custom_vjp bit-identically across
    # backends (kernel fwd keeps the graph dual as its backward), and
    # match autodiff of the unfused oracle
    def make_grad(backend, mode="ring"):
        def f(a, w):
            out = toy_ag(a, w, axis="tp", mode=mode, backend=backend,
                         out_dtype=jnp.float32)
            return lax.psum(jnp.sum(out * out), "tp")
        return sh(jax.grad(f, argnums=(0, 1)),
                  (P("tp", None), P(None, "tp")),
                  (P("tp", None), P(None, "tp")))

    gg = [np.asarray(t) for t in make_grad("graph")(A, Wt)]
    gk = [np.asarray(t) for t in make_grad("kernel")(A, Wt)]
    for a, b in zip(gg, gk):
        assert np.array_equal(a, b), "toy_ag grads differ across backends"
    for a, b in zip(make_grad("graph", "bidir")(A, Wt),
                    make_grad("kernel", "bidir")(A, Wt)):
        assert np.array_equal(np.asarray(a), np.asarray(b)), \
            "toy_ag bidir grads differ across backends"

    def oracle(a, w):
        out = jnp.dot(lax.all_gather(a, "tp", tiled=True), jnp.tanh(w),
                      preferred_element_type=jnp.float32)
        return lax.psum(jnp.sum(out * out), "tp")

    go = sh(jax.grad(oracle, argnums=(0, 1)),
            (P("tp", None), P(None, "tp")),
            (P("tp", None), P(None, "tp")))(A, Wt)
    for a, b in zip(gg, [np.asarray(t) for t in go]):
        assert np.abs(a - b).max() < 1e-3, "toy_ag grads vs oracle"
    print("OK toy ops", W)
""")


@pytest.mark.parametrize("world", [2, 4, 8])
def test_toy_op_declaration_registry_parity_grads(world):
    out = run_devices(TOY.replace("__WORLD__", str(world)), devices=world,
                      timeout=1200)
    assert "OK" in out


FUSED = textwrap.dedent("""
    import functools
    import jax, jax.numpy as jnp, numpy as np
    from jax import lax
    from jax.sharding import PartitionSpec as P
    from repro import ops

    W = __WORLD__
    mesh = jax.make_mesh((W,), ("tp",), axis_types=(jax.sharding.AxisType.Auto,))
    rng = np.random.RandomState(0)

    M, K, N, F = 4 * W, 2 * W, 6, 3 * W
    Y = jnp.asarray(rng.randn(M, K), jnp.float32)
    WO = jnp.asarray(rng.randn(K, N), jnp.float32)
    WI = jnp.asarray(rng.randn(N, F), jnp.float32)
    XR = jnp.asarray(rng.randn(M, N), jnp.float32)

    def boundary(r, x):
        # rank-local seam: residual add + nonlinearity (rows stay rows)
        return jnp.tanh(r + x.astype(r.dtype))

    IN = (P(None, "tp"), P("tp", None), P(None, "tp"), P("tp", None))
    OUT = P(None, "tp")

    def sh(fn, in_specs=IN, out_specs=OUT):
        return jax.jit(jax.shard_map(fn, mesh=mesh, in_specs=in_specs,
                                     out_specs=out_specs, check_vma=False))

    def run(mode, backend="graph", chunks=1):
        f = sh(functools.partial(
            ops.matmul_rs_ag_matmul, axis="tp", mode=mode, backend=backend,
            chunks=chunks, out_dtype=jnp.float32, mid=boundary))
        return np.asarray(f(Y, WO, WI, XR))

    # the composed unfused pair on XLA collectives is the oracle; the
    # documented tolerance vs every fused lowering is f32-accumulation
    # rounding (identical FLOPs, reassociated across the seam)
    def composed(y, wo, wi, x):
        r = ops.matmul_rs(y, wo, axis="tp", mode="none",
                          out_dtype=jnp.float32)
        h = boundary(r, x)
        return ops.ag_matmul(h, wi, axis="tp", mode="none",
                             out_dtype=jnp.float32)

    want = np.asarray(sh(composed)(Y, WO, WI, XR))
    # mode "none" IS the registered composed-pair baseline
    assert np.abs(run("none") - want).max() < 1e-5, "baseline vs composed"
    for label, out in (("ring", run("ring")),
                       ("ring-x2", run("ring", chunks=2)),
                       ("one_shot", run("one_shot"))):
        assert np.abs(out - want).max() < 1e-5, ("fused graph", label)

    # graph-vs-kernel parity on the chained push_rs -> ring_ag protocol
    for chunks in (1, 2):
        k = run("ring", backend="kernel", chunks=chunks)
        g = run("ring", backend="graph", chunks=chunks)
        assert np.abs(k - g).max() < 1e-5, ("fused kernel parity", chunks)

    # grads: fused-vs-composed close under a quadratic loss; graph-vs-
    # kernel bit-identical under a FIXED cotangent (linear loss) — the
    # shared custom_vjp recomputes on a fixed graph path, so the
    # backward never depends on which backend ran the forward
    GSPECS = dict(in_specs=IN, out_specs=IN)

    def make_grad(fn, quad=True):
        def loss(y, wo, wi, x):
            out = fn(y, wo, wi, x)
            return lax.psum(jnp.sum(out * out if quad else out), "tp")
        return jax.jit(jax.shard_map(jax.grad(loss, argnums=(0, 1, 2, 3)),
                                     mesh=mesh, check_vma=False, **GSPECS))

    def fused_fn(backend):
        return functools.partial(
            ops.matmul_rs_ag_matmul, axis="tp", mode="ring", backend=backend,
            out_dtype=jnp.float32, mid=boundary)

    go = [np.asarray(t) for t in make_grad(composed)(Y, WO, WI, XR)]
    gg = [np.asarray(t) for t in make_grad(fused_fn("graph"))(Y, WO, WI, XR)]
    for a, b in zip(gg, go):
        rel = np.abs(a - b).max() / max(1.0, np.abs(b).max())
        assert rel < 1e-5, ("fused grads vs composed", rel)
    lg = make_grad(fused_fn("graph"), quad=False)(Y, WO, WI, XR)
    lk = make_grad(fused_fn("kernel"), quad=False)(Y, WO, WI, XR)
    for a, b in zip(lg, lk):
        assert np.array_equal(np.asarray(a), np.asarray(b)), \
            "fused grads differ across backends"
    print("OK fused", W)
""")


@pytest.mark.parametrize("world", [2, 4, 8])
def test_fused_boundary_matches_composed_pair_and_grads(world):
    out = run_devices(FUSED.replace("__WORLD__", str(world)), devices=world,
                      timeout=1200)
    assert "OK" in out


# ---------------------------------------------------------------------------
# OverlapPolicy resolution (single device, registry-backed)
# ---------------------------------------------------------------------------


def test_policy_single_resolution_point():
    from repro import hw, ops

    pol = ops.OverlapPolicy(mode="ring", backend="kernel",
                            ag_chunks=2, rs_chunks=3)
    r = pol.resolve("ag_matmul")
    assert r == ops.ResolvedOverlap("ring", "kernel", 2)
    # chunk count picked by registry kind (rs ops use the rs knob)
    assert pol.resolve("matmul_rs").chunks == 3
    # mode clamped by the registry: a2a_ep has no ring transport
    assert pol.resolve("a2a_ep").mode == "one_shot"
    # backend degraded off kernel-incapable pairs (bidir ag_matmul is
    # kernel-capable since the bidir_ring_ag protocol; moe_rs/bidir
    # still degrades). ring_attention is kernel-capable since the
    # carry-passing ring_fold protocol — no engine-internal degrade left.
    assert pol.with_modes(ag_matmul="bidir").resolve("ag_matmul").backend == \
        "kernel"
    assert pol.with_modes(moe_rs="bidir").resolve("moe_rs").backend == "graph"
    assert pol.resolve("ring_attention").backend == "kernel"
    assert pol.resolve("ag_matmul_2level") == ops.ResolvedOverlap(
        "two_level", "kernel", 2)
    # hw-aware degrade: no ICI links -> no remote-DMA engine -> graph
    no_ici = dataclasses.replace(hw.DEFAULT, ici_links=0)
    assert pol.resolve("ag_matmul", hw=no_ici).backend == "graph"
    assert pol.resolve("ag_matmul", hw=hw.DEFAULT).backend == "kernel"
    # dict ergonomics + describe
    pol2 = ops.OverlapPolicy(modes={"ag_matmul": "one_shot"})
    assert pol2.mode_for("ag_matmul") == "one_shot"
    assert pol2.describe("ag_matmul") == "one_shot/graph"


def test_policy_shape_keyed_layer_rules():
    from repro import ops

    pol = ops.OverlapPolicy(mode="ring")
    # the fused boundary op defaults OFF (mode "none") until opted in
    assert pol.mode_for("matmul_rs_ag_matmul") == "none"
    shape = ((512, 1024), (1024, 4096))
    pol = pol.with_layer("ag_matmul", shape, mode="one_shot", chunks=4)
    # the layer rule wins at ITS shape only; base resolution elsewhere
    r = pol.resolve("ag_matmul", shape=shape)
    assert (r.mode, r.chunks) == ("one_shot", 4)
    assert pol.resolve("ag_matmul", shape=((256, 1024), (1024, 4096))).mode \
        == "ring"
    assert pol.resolve("ag_matmul").mode == "ring"
    # shape keys flatten: list/tuple/int spellings hit the same rule
    assert ops.shape_key([512, 1024, 1024, 4096]) == \
        ops.shape_key(((512, 1024), (1024, 4096)))
    # layer overrides are re-clamped by the registry (a2a has no ring)
    pol2 = ops.OverlapPolicy().with_layer("a2a_ep", (8,), mode="ring")
    assert pol2.resolve("a2a_ep", shape=(8,)).mode == "one_shot"
    # JSON round-trip preserves base knobs AND layer rules
    back = ops.OverlapPolicy.from_json(pol.to_json())
    assert back == pol
    assert back.resolve("ag_matmul", shape=shape).chunks == 4


def test_parallel_config_carries_policy():
    from repro import ops
    from repro.configs.base import ParallelConfig

    # legacy fields fold into an equivalent policy on the fly
    legacy = ParallelConfig(tp=4, overlap_mode="one_shot", ag_chunks=2)
    explicit = ParallelConfig(
        tp=4, overlap=ops.OverlapPolicy(mode="one_shot", ag_chunks=2))
    for op in ("ag_matmul", "matmul_rs", "a2a_ep", "flash_decode"):
        assert legacy.policy.resolve(op) == explicit.policy.resolve(op), op
    # legacy fields AT their defaults are indistinguishable from unset:
    # the explicit policy simply wins
    both = ParallelConfig(tp=4, overlap_mode="ring",
                          overlap=ops.OverlapPolicy(mode="one_shot"))
    assert both.policy.resolve("ag_matmul").mode == "one_shot"


def test_declaration_validation_guards():
    """Declaration-time guards for backend-divergence hazards: a
    bidir_ring_ag binding needs a rowwise tile (the protocol tiles chunk
    HALVES), and a2a kernel protocols need tile=None (graph applies an
    a2a tile post-assembly, the protocol per landed block)."""
    from repro import ops

    with pytest.raises(ValueError, match="rowwise"):
        ops.OverlapOp(name="bad_bidir", kind="ag", tile=None,
                      transports=("ring", "bidir"),
                      kernel_protocols=(("bidir", "bidir_ring_ag"),))
    with pytest.raises(ValueError, match="tile=None"):
        ops.OverlapOp(name="bad_a2a", kind="a2a", tile=lambda x: 2 * x,
                      transports=("one_shot",), baseline="xla",
                      default="one_shot",
                      kernel_protocols=(("one_shot", "one_shot_a2a"),))


def test_conflicting_policy_and_legacy_fields_raise():
    """An explicit ``overlap`` policy plus NON-default legacy overlap
    fields is two sources of truth — a clear ValueError, not a silent
    preference (both argument orders)."""
    from repro import ops
    from repro.configs.base import ParallelConfig

    pol = ops.OverlapPolicy(mode="one_shot")
    with pytest.raises(ValueError, match="overlap_mode"):
        ParallelConfig(tp=4, overlap=pol, overlap_mode="bidir")
    with pytest.raises(ValueError, match="overlap_mode"):
        ParallelConfig(tp=4, overlap_mode="bidir", overlap=pol)
    # every legacy knob participates in the conflict check
    with pytest.raises(ValueError, match="ag_chunks"):
        ParallelConfig(tp=4, overlap=pol, ag_chunks=2)
    with pytest.raises(ValueError, match="overlap_backend"):
        ParallelConfig(tp=4, overlap_backend="kernel", overlap=pol)
    with pytest.raises(ValueError, match="overlap_modes"):
        ParallelConfig(tp=4, overlap=pol,
                       overlap_modes={"ag_matmul": "one_shot"})
    # non-overlap fields never conflict; policy-only configs are fine
    ParallelConfig(tp=4, overlap=pol, remat="none", moe_chunks=2)


def test_tuner_policy_feeds_default_pcfg_without_repacking():
    from repro import ops
    from repro.configs import ARCHS, reduced
    from repro.configs.shapes import SHAPES
    from repro.launch.steps import default_pcfg

    cfg = reduced(ARCHS["granite-3-2b"])
    shape = SHAPES["train_4k"]
    pcfg = default_pcfg(cfg, shape, multi_pod=False, overlap_mode="auto")
    assert isinstance(pcfg.overlap, ops.OverlapPolicy)
    # the tuner's policy resolves every registry op without error and the
    # CPU host recommendation is the graph backend
    r = pcfg.policy.resolve("ag_matmul")
    assert r.backend == "graph"
    assert r.chunks >= 1
    # explicit per-op pairs still win over the tuner's picks
    pcfg2 = default_pcfg(cfg, shape, multi_pod=False, overlap_mode="auto",
                         overlap_modes=(("ag_matmul", "ring"),))
    assert pcfg2.policy.resolve("ag_matmul").mode == "ring"
