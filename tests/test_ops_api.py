"""repro.ops authoring-API tests.

1. Declaring a toy op IN-TEST via ``OverlapOp`` auto-appears in the
   engine registry with derived graph + kernel lowerings and the derived
   dual-schedule backward; it passes graph-vs-kernel parity at worlds
   2/4/8 and round-trips grads bit-identically through the ONE shared
   custom_vjp (kernel forward keeps the graph dual as its backward).
2. Back-compat shims: string-keyed ``overlap.apply`` and
   ``ParallelConfig.with_modes/with_backends`` keep working but emit a
   ``DeprecationWarning`` naming the replacement, and the shim path is
   bit-identical to the new ``repro.ops`` path.
3. ``OverlapPolicy``: single-point resolution (mode clamped by the
   registry, backend degraded off kernel-incapable pairs, chunk count
   picked by op kind), dict ergonomics, hw-aware degrade.
"""
import dataclasses
import textwrap
import warnings

import pytest

from conftest import run_devices

TOY = textwrap.dedent("""
    import functools
    import jax, jax.numpy as jnp, numpy as np
    from jax import lax
    from jax.sharding import PartitionSpec as P
    from repro import ops
    from repro.core import overlap as ov

    W = __WORLD__
    mesh = jax.make_mesh((W,), ("tp",), axis_types=(jax.sharding.AxisType.Auto,))
    rng = np.random.RandomState(0)

    # ---- declare toy ops IN-TEST (nonlinear in the static operand) ----
    assert "toy_ag" not in ov.registry()
    toy_tile = lambda c, w: jnp.dot(c, jnp.tanh(w),
                                    preferred_element_type=jnp.float32)
    toy_ag = ops.declare(ops.OverlapOp(
        name="toy_ag", kind="ag", tile=toy_tile,
        transports=("ring", "bidir", "one_shot"),
        kernel_protocols=(("ring", "ring_ag"), ("bidir", "bidir_ring_ag"),
                          ("one_shot", "one_shot_ag")),
        transpose="matmul_rs", rowwise=True))
    toy_rs = ops.declare(ops.OverlapOp(
        name="toy_rs", kind="rs", tile=toy_tile,
        transports=("ring", "one_shot"),
        kernel_protocols=(("ring", "push_rs"), ("one_shot", "one_shot_rs")),
        transpose="toy_ag"))

    # auto-registration: spec with derived fwd/bwd/kernel_fwd appears
    spec = ov.get("toy_ag")
    assert spec.kind == "ag"
    assert spec.kernel_transports == ("ring", "bidir", "one_shot")
    assert spec.fwd is not None and spec.bwd is not None
    assert spec.kernel_fwd is not None
    # ...and is immediately visible to tuner candidate enumeration and
    # policy resolution, with no extra wiring
    assert ov.transports_for("toy_ag") == ("ring", "bidir", "one_shot")
    assert ov.backends_for("toy_rs") == ("graph", "kernel")
    pol = ops.OverlapPolicy(mode="ring", backend="kernel")
    assert pol.resolve("toy_ag").backend == "kernel"
    assert pol.resolve("toy_rs").backend == "kernel"
    assert pol.resolve("toy_ag", hw=None).mode == "ring"

    def sh(fn, in_specs, out_specs):
        return jax.jit(jax.shard_map(fn, mesh=mesh, in_specs=in_specs,
                                     out_specs=out_specs, check_vma=False))

    M, K, N = 4 * W, 8, 2 * W
    A = jnp.asarray(rng.randn(M, K), jnp.float32)
    Wt = jnp.asarray(rng.randn(K, N), jnp.float32)
    want = np.asarray(A) @ np.tanh(np.asarray(Wt))

    AG_SPECS = ((P("tp", None), P(None, "tp")), P(None, "tp"))
    # derived graph lowering matches the oracle on every transport
    for mode in ("none", "ring", "bidir", "one_shot"):
        f = sh(functools.partial(toy_ag, axis="tp", mode=mode,
                                 out_dtype=jnp.float32), *AG_SPECS)
        err = np.abs(np.asarray(f(A, Wt)) - want).max()
        assert err < 2e-4, ("toy_ag", mode, err)

    # graph-vs-kernel parity for every declared (transport, protocol)
    def run(op, specs, mode, backend, *xs):
        f = sh(functools.partial(op, axis="tp", mode=mode, backend=backend,
                                 out_dtype=jnp.float32), *specs)
        return np.asarray(f(*xs))

    for mode in ("ring", "bidir", "one_shot"):
        k = run(toy_ag, AG_SPECS, mode, "kernel", A, Wt)
        g = run(toy_ag, AG_SPECS, mode, "graph", A, Wt)
        assert np.abs(k - g).max() < 2e-4, ("toy_ag kernel", mode)

    RS_SPECS = ((P(None, "tp"), P("tp", None)), P("tp", None))
    A2 = jnp.asarray(rng.randn(M, 4 * W), jnp.float32)
    W2 = jnp.asarray(rng.randn(4 * W, N), jnp.float32)
    want2 = np.asarray(A2) @ np.tanh(np.asarray(W2))
    for mode in ("none", "ring", "one_shot"):
        g = run(toy_rs, RS_SPECS, mode, "graph", A2, W2)
        assert np.abs(g - want2).max() < 2e-4, ("toy_rs", mode)
    for mode in ("ring", "one_shot"):
        k = run(toy_rs, RS_SPECS, mode, "kernel", A2, W2)
        g = run(toy_rs, RS_SPECS, mode, "graph", A2, W2)
        assert np.abs(k - g).max() < 2e-4, ("toy_rs kernel", mode)

    # grads round-trip the SHARED custom_vjp bit-identically across
    # backends (kernel fwd keeps the graph dual as its backward), and
    # match autodiff of the unfused oracle
    def make_grad(backend, mode="ring"):
        def f(a, w):
            out = toy_ag(a, w, axis="tp", mode=mode, backend=backend,
                         out_dtype=jnp.float32)
            return lax.psum(jnp.sum(out * out), "tp")
        return sh(jax.grad(f, argnums=(0, 1)),
                  (P("tp", None), P(None, "tp")),
                  (P("tp", None), P(None, "tp")))

    gg = [np.asarray(t) for t in make_grad("graph")(A, Wt)]
    gk = [np.asarray(t) for t in make_grad("kernel")(A, Wt)]
    for a, b in zip(gg, gk):
        assert np.array_equal(a, b), "toy_ag grads differ across backends"
    for a, b in zip(make_grad("graph", "bidir")(A, Wt),
                    make_grad("kernel", "bidir")(A, Wt)):
        assert np.array_equal(np.asarray(a), np.asarray(b)), \
            "toy_ag bidir grads differ across backends"

    def oracle(a, w):
        out = jnp.dot(lax.all_gather(a, "tp", tiled=True), jnp.tanh(w),
                      preferred_element_type=jnp.float32)
        return lax.psum(jnp.sum(out * out), "tp")

    go = sh(jax.grad(oracle, argnums=(0, 1)),
            (P("tp", None), P(None, "tp")),
            (P("tp", None), P(None, "tp")))(A, Wt)
    for a, b in zip(gg, [np.asarray(t) for t in go]):
        assert np.abs(a - b).max() < 1e-3, "toy_ag grads vs oracle"
    print("OK toy ops", W)
""")


@pytest.mark.parametrize("world", [2, 4, 8])
def test_toy_op_declaration_registry_parity_grads(world):
    out = run_devices(TOY.replace("__WORLD__", str(world)), devices=world,
                      timeout=1200)
    assert "OK" in out


SHIM = textwrap.dedent("""
    import functools, warnings
    import jax, jax.numpy as jnp, numpy as np
    from jax import lax
    from jax.sharding import PartitionSpec as P
    from repro import ops
    from repro.core import overlap as ov

    W = 4
    mesh = jax.make_mesh((W,), ("tp",), axis_types=(jax.sharding.AxisType.Auto,))
    rng = np.random.RandomState(0)
    A = jnp.asarray(rng.randn(8 * W, 16), jnp.float32)
    B = jnp.asarray(rng.randn(16, 4 * W), jnp.float32)

    def sh(fn):
        return jax.jit(jax.shard_map(fn, mesh=mesh,
                                     in_specs=(P("tp", None), P(None, "tp")),
                                     out_specs=P(None, "tp"), check_vma=False))

    new = sh(functools.partial(ops.ag_matmul, axis="tp", mode="ring",
                               out_dtype=jnp.float32))(A, B)

    # the string-keyed shim warns (naming the replacement) and is
    # bit-identical to the new path — forward AND gradients
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        old = sh(lambda a, b: ov.apply("ag_matmul", a, b, axis="tp",
                                       mode="ring", out_dtype="float32"))(A, B)
    assert any(issubclass(w.category, DeprecationWarning) and
               "repro.ops" in str(w.message) for w in rec), \
        [str(w.message) for w in rec]
    assert np.array_equal(np.asarray(old), np.asarray(new)), "shim != new path"

    def loss_new(a, b):
        out = ops.ag_matmul(a, b, axis="tp", mode="ring", out_dtype=jnp.float32)
        return lax.psum(jnp.sum(out * out), "tp")

    def loss_old(a, b):
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            out = ov.apply("ag_matmul", a, b, axis="tp", mode="ring",
                           out_dtype="float32")
        return lax.psum(jnp.sum(out * out), "tp")

    gspecs = dict(in_specs=(P("tp", None), P(None, "tp")),
                  out_specs=(P("tp", None), P(None, "tp")))
    gn = jax.jit(jax.shard_map(jax.grad(loss_new, argnums=(0, 1)), mesh=mesh,
                               check_vma=False, **gspecs))(A, B)
    go = jax.jit(jax.shard_map(jax.grad(loss_old, argnums=(0, 1)), mesh=mesh,
                               check_vma=False, **gspecs))(A, B)
    for a, b in zip(gn, go):
        assert np.array_equal(np.asarray(a), np.asarray(b)), "shim grads"
    print("OK shim")
""")


def test_string_keyed_apply_shim_warns_and_matches():
    out = run_devices(SHIM, devices=4)
    assert "OK" in out


# ---------------------------------------------------------------------------
# OverlapPolicy resolution (single device, registry-backed)
# ---------------------------------------------------------------------------


def test_policy_single_resolution_point():
    from repro import hw, ops

    pol = ops.OverlapPolicy(mode="ring", backend="kernel",
                            ag_chunks=2, rs_chunks=3)
    r = pol.resolve("ag_matmul")
    assert r == ops.ResolvedOverlap("ring", "kernel", 2)
    # chunk count picked by registry kind (rs ops use the rs knob)
    assert pol.resolve("matmul_rs").chunks == 3
    # mode clamped by the registry: a2a_ep has no ring transport
    assert pol.resolve("a2a_ep").mode == "one_shot"
    # backend degraded off kernel-incapable pairs (bidir ag_matmul is
    # kernel-capable since the bidir_ring_ag protocol; moe_rs/bidir
    # still degrades). ring_attention is kernel-capable since the
    # carry-passing ring_fold protocol — no engine-internal degrade left.
    assert pol.with_modes(ag_matmul="bidir").resolve("ag_matmul").backend == \
        "kernel"
    assert pol.with_modes(moe_rs="bidir").resolve("moe_rs").backend == "graph"
    assert pol.resolve("ring_attention").backend == "kernel"
    assert pol.resolve("ag_matmul_2level") == ops.ResolvedOverlap(
        "two_level", "kernel", 2)
    # hw-aware degrade: no ICI links -> no remote-DMA engine -> graph
    no_ici = dataclasses.replace(hw.DEFAULT, ici_links=0)
    assert pol.resolve("ag_matmul", hw=no_ici).backend == "graph"
    assert pol.resolve("ag_matmul", hw=hw.DEFAULT).backend == "kernel"
    # dict ergonomics + describe
    pol2 = ops.OverlapPolicy(modes={"ag_matmul": "one_shot"})
    assert pol2.mode_for("ag_matmul") == "one_shot"
    assert pol2.describe("ag_matmul") == "one_shot/graph"


def test_parallel_config_carries_policy():
    from repro import ops
    from repro.configs.base import ParallelConfig

    # legacy fields fold into an equivalent policy on the fly
    legacy = ParallelConfig(tp=4, overlap_mode="one_shot", ag_chunks=2)
    explicit = ParallelConfig(
        tp=4, overlap=ops.OverlapPolicy(mode="one_shot", ag_chunks=2))
    for op in ("ag_matmul", "matmul_rs", "a2a_ep", "flash_decode"):
        assert legacy.policy.resolve(op) == explicit.policy.resolve(op), op
    # legacy fields AT their defaults are indistinguishable from unset:
    # the explicit policy simply wins
    both = ParallelConfig(tp=4, overlap_mode="ring",
                          overlap=ops.OverlapPolicy(mode="one_shot"))
    assert both.policy.resolve("ag_matmul").mode == "one_shot"


def test_declaration_validation_guards():
    """Declaration-time guards for backend-divergence hazards: a
    bidir_ring_ag binding needs a rowwise tile (the protocol tiles chunk
    HALVES), and a2a kernel protocols need tile=None (graph applies an
    a2a tile post-assembly, the protocol per landed block)."""
    from repro import ops

    with pytest.raises(ValueError, match="rowwise"):
        ops.OverlapOp(name="bad_bidir", kind="ag", tile=None,
                      transports=("ring", "bidir"),
                      kernel_protocols=(("bidir", "bidir_ring_ag"),))
    with pytest.raises(ValueError, match="tile=None"):
        ops.OverlapOp(name="bad_a2a", kind="a2a", tile=lambda x: 2 * x,
                      transports=("one_shot",), baseline="xla",
                      default="one_shot",
                      kernel_protocols=(("one_shot", "one_shot_a2a"),))


def test_conflicting_policy_and_legacy_fields_raise():
    """An explicit ``overlap`` policy plus NON-default legacy overlap
    fields is two sources of truth — a clear ValueError, not a silent
    preference (both argument orders)."""
    from repro import ops
    from repro.configs.base import ParallelConfig

    pol = ops.OverlapPolicy(mode="one_shot")
    with pytest.raises(ValueError, match="overlap_mode"):
        ParallelConfig(tp=4, overlap=pol, overlap_mode="bidir")
    with pytest.raises(ValueError, match="overlap_mode"):
        ParallelConfig(tp=4, overlap_mode="bidir", overlap=pol)
    # every legacy knob participates in the conflict check
    with pytest.raises(ValueError, match="ag_chunks"):
        ParallelConfig(tp=4, overlap=pol, ag_chunks=2)
    with pytest.raises(ValueError, match="overlap_backend"):
        ParallelConfig(tp=4, overlap_backend="kernel", overlap=pol)
    with pytest.raises(ValueError, match="overlap_modes"):
        ParallelConfig(tp=4, overlap=pol,
                       overlap_modes={"ag_matmul": "one_shot"})
    # non-overlap fields never conflict; policy-only configs are fine
    ParallelConfig(tp=4, overlap=pol, remat="none", moe_chunks=2)


def test_shim_warnings_point_at_the_caller():
    """The DeprecationWarning shims carry the right ``stacklevel``: the
    reported filename is THIS test file, not the shim's module."""
    import warnings

    import jax.numpy as jnp

    from repro.configs.base import ParallelConfig
    from repro.core import overlap as ov

    pcfg = ParallelConfig(tp=4)
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        pcfg.with_modes(ag_matmul="one_shot")
        pcfg.with_backends(matmul_rs="kernel")
        try:
            # outside shard_map the dispatch fails on the missing mesh
            # axis — AFTER the shim has already warned
            ov.apply("ag_matmul", jnp.zeros((2, 2)), jnp.zeros((2, 2)),
                     axis="tp", mode="ring", out_dtype="float32")
        except Exception:
            pass
    deps = [w for w in rec if issubclass(w.category, DeprecationWarning)
            and "deprecated" in str(w.message)]
    assert len(deps) == 3, [str(w.message) for w in rec]
    for w in deps:
        assert w.filename == __file__, (w.filename, str(w.message))


def test_with_modes_shim_warns_and_matches_policy_path():
    from repro.configs.base import ParallelConfig

    pcfg = ParallelConfig(tp=4)
    with pytest.warns(DeprecationWarning, match="OverlapPolicy"):
        old = pcfg.with_modes(ag_matmul="one_shot")
    new = dataclasses.replace(
        pcfg, overlap=pcfg.policy.with_modes(ag_matmul="one_shot"))
    with pytest.warns(DeprecationWarning, match="OverlapPolicy"):
        old = old.with_backends(matmul_rs="kernel")
    new = dataclasses.replace(
        new, overlap=new.policy.with_backends(matmul_rs="kernel"))
    for op in ("ag_matmul", "matmul_rs", "a2a_ep"):
        assert old.policy.resolve(op) == new.policy.resolve(op), op
    # with_modes on a policy-carrying config merges into the policy
    with pytest.warns(DeprecationWarning):
        merged = new.with_modes(matmul_rs="one_shot")
    assert merged.overlap is not None
    assert merged.policy.resolve("matmul_rs").mode == "one_shot"


def test_tuner_policy_feeds_default_pcfg_without_repacking():
    from repro import ops
    from repro.configs import ARCHS, reduced
    from repro.configs.shapes import SHAPES
    from repro.launch.steps import default_pcfg

    cfg = reduced(ARCHS["granite-3-2b"])
    shape = SHAPES["train_4k"]
    pcfg = default_pcfg(cfg, shape, multi_pod=False, overlap_mode="auto")
    assert isinstance(pcfg.overlap, ops.OverlapPolicy)
    # the tuner's policy resolves every registry op without error and the
    # CPU host recommendation is the graph backend
    r = pcfg.policy.resolve("ag_matmul")
    assert r.backend == "graph"
    assert r.chunks >= 1
    # explicit per-op pairs still win over the tuner's picks
    pcfg2 = default_pcfg(cfg, shape, multi_pod=False, overlap_mode="auto",
                         overlap_modes=(("ag_matmul", "ring"),))
    assert pcfg2.policy.resolve("ag_matmul").mode == "ring"
