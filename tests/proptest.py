"""Minimal property-based testing shim.

``hypothesis`` is not installable in this offline container (recorded in
DESIGN.md); this module provides the subset we need: seeded random
strategies + a @given decorator that runs the property across N sampled
inputs and reports the failing example.
"""
from __future__ import annotations

from typing import Callable

import numpy as np


class Strategy:
    def __init__(self, sample: Callable[[np.random.RandomState], object], name=""):
        self.sample = sample
        self.name = name


def integers(lo: int, hi: int) -> Strategy:
    return Strategy(lambda r: int(r.randint(lo, hi + 1)), f"int[{lo},{hi}]")


def sampled_from(options) -> Strategy:
    opts = list(options)
    return Strategy(lambda r: opts[r.randint(len(opts))], f"from{opts}")


def floats(lo: float, hi: float) -> Strategy:
    return Strategy(lambda r: float(r.uniform(lo, hi)), f"float[{lo},{hi}]")


def arrays(shape_strategy, scale: float = 1.0, dtype=np.float32) -> Strategy:
    def sample(r):
        shape = shape_strategy.sample(r) if isinstance(shape_strategy, Strategy) else shape_strategy
        return (r.randn(*shape) * scale).astype(dtype)

    return Strategy(sample, "array")


def given(examples: int = 25, seed: int = 0, **strategies):
    """Run the test with ``examples`` sampled inputs."""

    def deco(fn):
        # NOTE: no functools.wraps — pytest must not see the strategy
        # parameter names as fixture requests.
        def wrapper():
            rng = np.random.RandomState(seed)
            for i in range(examples):
                drawn = {k: s.sample(rng) for k, s in strategies.items()}
                try:
                    fn(**drawn)
                except Exception as e:  # noqa: BLE001
                    raise AssertionError(
                        f"property failed on example {i}: {drawn!r}: {e}"
                    ) from e

        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        return wrapper

    return deco
