"""Checkpointer: roundtrip, atomic commit, GC, elastic repack."""
import os

import jax.numpy as jnp
import numpy as np

from repro.configs.base import ParallelConfig
from repro.models.params import LeafSpec
from repro.train.checkpoint import Checkpointer, repack_leaf


def _state():
    return {
        "params": {"w": jnp.arange(12.0).reshape(3, 4), "b": jnp.ones((5,))},
        "opt": {"step": jnp.int32(7)},
    }


def test_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    st = _state()
    ck.save(10, st, blocking=True)
    assert ck.latest_step() == 10
    out = ck.restore(10, st)
    np.testing.assert_array_equal(np.asarray(out["params"]["w"]),
                                  np.asarray(st["params"]["w"]))
    assert int(out["opt"]["step"]) == 7


def test_gc_keeps_latest(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        ck.save(s, _state(), blocking=True)
    assert sorted(ck.steps()) == [3, 4]


def test_async_save_then_wait(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    ck.save(5, _state(), blocking=False)
    ck.wait()
    assert ck.latest_step() == 5


def test_no_tmp_dirs_left(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    ck.save(1, _state(), blocking=True)
    assert not [d for d in os.listdir(tmp_path) if d.endswith(".tmp")]


def test_repack_leaf_dp_change():
    """Elastic restart: repack a tp-sharded packed leaf from dp=4 to dp=2."""
    spec = LeafSpec((5, 3))  # numel 15
    old = ParallelConfig(dp=4, tp=2)
    new = ParallelConfig(dp=2, tp=2)
    seg_old = ((15 + 3) // 4) * 4  # 16
    rng = np.random.RandomState(0)
    segs = [rng.randn(15) for _ in range(2)]
    packed = np.concatenate([np.concatenate([s, np.zeros(seg_old - 15)]) for s in segs])
    out = repack_leaf(packed, spec, old, new)
    seg_new = ((15 + 1) // 2) * 2  # 16
    assert out.shape == (2 * seg_new,)
    for r in range(2):
        np.testing.assert_allclose(out[r * seg_new: r * seg_new + 15], segs[r])


def test_repack_stacked_leaf():
    spec = LeafSpec((7,), tp_sharded=False)
    old = ParallelConfig(dp=4, tp=1)
    new = ParallelConfig(dp=8, tp=1)
    rng = np.random.RandomState(1)
    seg_old = 8
    rows = []
    for _ in range(3):
        v = rng.randn(7)
        rows.append(np.concatenate([v, np.zeros(seg_old - 7)]))
    packed = np.stack(rows)
    out = repack_leaf(packed, spec, old, new)
    assert out.shape == (3, 8)  # ceil(7/8)*8 = 8
    np.testing.assert_allclose(out[:, :7], packed[:, :7])
