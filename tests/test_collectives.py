"""Overlapped collective matmuls vs. XLA oracles on 8 virtual devices
(subprocess — the main pytest process keeps 1 device)."""
import textwrap

from conftest import run_devices

SCRIPT = textwrap.dedent("""
    import jax, jax.numpy as jnp, numpy as np, functools
    from jax.sharding import PartitionSpec as P
    from repro.core import collective_matmul as cm

    mesh = jax.make_mesh((8,), ("tp",), axis_types=(jax.sharding.AxisType.Auto,))
    rng = np.random.RandomState(0)
    M, K, N = 64, 32, 48
    A = jnp.asarray(rng.randn(M, K), jnp.float32)
    B = jnp.asarray(rng.randn(K, N), jnp.float32)
    want = np.asarray(A @ B)
    for mode in ["none", "ring", "bidir", "one_shot"]:
        f = cm.make_sharded(functools.partial(cm.ag_matmul, axis="tp", mode=mode,
                                              out_dtype=jnp.float32),
                            mesh, (P("tp", None), P(None, "tp")), P(None, "tp"))
        got = np.asarray(f(A, B))
        assert np.abs(got - want).max() < 1e-4, mode
    # sub-chunked ring
    f = cm.make_sharded(functools.partial(cm.ag_matmul, axis="tp", mode="ring",
                                          chunks_per_rank=2, out_dtype=jnp.float32),
                        mesh, (P("tp", None), P(None, "tp")), P(None, "tp"))
    assert np.abs(np.asarray(f(A, B)) - want).max() < 1e-4

    A2 = jnp.asarray(rng.randn(M, 64), jnp.float32)
    B2 = jnp.asarray(rng.randn(64, N), jnp.float32)
    want2 = np.asarray(A2 @ B2)
    for mode in ["none", "ring"]:
        f = cm.make_sharded(functools.partial(cm.matmul_rs, axis="tp", mode=mode,
                                              out_dtype=jnp.float32),
                            mesh, (P(None, "tp"), P("tp", None)), P("tp", None))
        assert np.abs(np.asarray(f(A2, B2)) - want2).max() < 1e-4, mode

    mesh2 = jax.make_mesh((2, 4), ("pod", "tp"),
                          axis_types=(jax.sharding.AxisType.Auto,) * 2)
    f = cm.make_sharded(functools.partial(cm.matmul_rs_2level, inner_axis="tp",
                                          outer_axis="pod", out_dtype=jnp.float32),
                        mesh2, (P(None, ("pod", "tp")), P(("pod", "tp"), None)),
                        P(("pod", "tp"), None))
    assert np.abs(np.asarray(f(A2, B2)) - want2).max() < 1e-4

    x = jnp.asarray(rng.randn(64, 8), jnp.float32)
    for mode in ("ring", "one_shot"):
        f = cm.make_sharded(functools.partial(cm.all_gather_chunked, axis="tp",
                                              mode=mode),
                            mesh, P("tp", None), P(None, None))
        assert np.abs(np.asarray(f(x)) - np.asarray(x)).max() == 0, mode
    f = cm.make_sharded(functools.partial(cm.reduce_scatter_chunked, axis="tp"),
                        mesh, P(None, None), P("tp", None))
    assert np.abs(np.asarray(f(x)) - 8 * np.asarray(x)).max() < 1e-4
    f = cm.make_sharded(functools.partial(cm.hierarchical_reduce_scatter,
                                          inner_axis="tp", outer_axis="pod"),
                        mesh2, P(None, None), P("tp", None))
    assert np.abs(np.asarray(f(x)) - 8 * np.asarray(x)).max() < 1e-4
    print("OK")
""")


def test_overlapped_collectives_equal_oracles():
    out = run_devices(SCRIPT, devices=8)
    assert "OK" in out


A2A_SCRIPT = textwrap.dedent("""
    import jax, jax.numpy as jnp, numpy as np, functools
    from jax.sharding import PartitionSpec as P
    from repro.core import moe_overlap as mo
    from repro.core import flash_decode as fdm
    from repro.kernels import ref

    W, Eg, cap, d = 8, 16, 4, 8
    mesh = jax.make_mesh((W,), ("ep",), axis_types=(jax.sharding.AxisType.Auto,))
    rng = np.random.RandomState(0)
    xg = rng.randn(W, Eg, cap, d).astype(np.float32)
    xflat = jnp.asarray(xg.reshape(W * Eg, cap, d))
    e_local = Eg // W
    want = np.zeros((W, e_local, W, cap, d), np.float32)
    for r in range(W):
        for el in range(e_local):
            for src in range(W):
                want[r, el, src] = xg[src, r * e_local + el]
    want = want.reshape(W * e_local, W * cap, d)
    for mode in ("xla", "one_shot"):
        f = jax.jit(jax.shard_map(functools.partial(mo.a2a_ep, axis=None or "ep", mode=mode),
                    mesh=mesh, in_specs=P("ep", None, None),
                    out_specs=P("ep", None, None), check_vma=False))
        got = np.asarray(f(xflat))
        assert np.abs(got - want).max() == 0, ("fwd", mode)
        g = jax.jit(jax.shard_map(
            lambda x: mo.a2a_ep_inverse(mo.a2a_ep(x, "ep", mode=mode), "ep", mode=mode),
            mesh=mesh, in_specs=P("ep", None, None),
            out_specs=P("ep", None, None), check_vma=False))
        rt = np.asarray(g(xflat))
        assert np.abs(rt - xg.reshape(W * Eg, cap, d)).max() == 0, ("rt", mode)

    B, Hq, Hkv, S, Dh = 2, 4, 2, 64, 16
    q = jnp.asarray(rng.randn(B, Hq, Dh), jnp.float32)
    k = jnp.asarray(rng.randn(B, Hkv, S * 8, Dh), jnp.float32)
    v = jnp.asarray(rng.randn(B, Hkv, S * 8, Dh), jnp.float32)
    lens = jnp.full((B,), S * 8, jnp.int32)
    def ddecode(q, ks, vs, mode):
        ll = jnp.full((q.shape[0],), ks.shape[2], jnp.int32)
        return fdm.distributed_flash_decode(q, ks, vs, ll, "ep", mode=mode)
    want_o, _ = ref.flash_decode(q, k, v, length=lens)
    for mode in ("xla", "one_shot"):
        f = jax.jit(jax.shard_map(functools.partial(ddecode, mode=mode), mesh=mesh,
            in_specs=(P(None,), P(None, None, "ep", None), P(None, None, "ep", None)),
            out_specs=P(None,), check_vma=False))
        got = np.asarray(f(q, k, v))
        assert np.abs(got - np.asarray(want_o)).max() < 1e-5, mode
    print("OK")
""")


def test_a2a_and_distributed_decode():
    out = run_devices(A2A_SCRIPT, devices=8)
    assert "OK" in out


DISTKERNEL_SCRIPT = textwrap.dedent("""
    import jax, jax.numpy as jnp, numpy as np, functools
    from jax.sharding import PartitionSpec as P
    from repro.kernels.ag_gemm import ag_gemm
    from repro.kernels.ll_allgather import ll_allgather
    from repro.kernels.rs_gemm import rs_gemm

    for W in (2, 4, 8):
        mesh = jax.make_mesh((W,), ("tp",), axis_types=(jax.sharding.AxisType.Auto,))
        rng = np.random.RandomState(0)
        M, K, N = 16 * W, 32, 8 * W
        A = jnp.asarray(rng.randn(M, K), jnp.float32)
        B = jnp.asarray(rng.randn(K, N), jnp.float32)
        f = jax.jit(jax.shard_map(
            functools.partial(ag_gemm, axis="tp", world=W, out_dtype=jnp.float32),
            mesh=mesh, in_specs=(P("tp", None), P(None, "tp")),
            out_specs=P(None, "tp"), check_vma=False))
        got = np.asarray(f(A, B))
        assert np.abs(got - np.asarray(A @ B)).max() < 1e-4, W

        x = jnp.asarray(rng.randn(8 * W, 8), jnp.float32)
        g = jax.jit(jax.shard_map(
            functools.partial(ll_allgather, axis="tp", world=W),
            mesh=mesh, in_specs=P("tp", None), out_specs=P(None, None),
            check_vma=False))
        assert np.abs(np.asarray(g(x)) - np.asarray(x)).max() == 0, W

        # fused GEMM+RS (Alg. 3): K sharded, output block-scattered
        A2 = jnp.asarray(rng.randn(8 * W, 16 * W), jnp.float32)
        B2 = jnp.asarray(rng.randn(16 * W, 24), jnp.float32)
        h = jax.jit(jax.shard_map(
            functools.partial(rs_gemm, axis="tp", world=W, out_dtype=jnp.float32),
            mesh=mesh, in_specs=(P(None, "tp"), P("tp", None)),
            out_specs=P("tp", None), check_vma=False))
        assert np.abs(np.asarray(h(A2, B2)) - np.asarray(A2 @ B2)).max() < 1e-4, W
    print("OK")
""")


def test_distributed_pallas_kernels():
    """ag_gemm (Fig. 4 fused kernel, remote DMA + signals) and the
    low-latency AllGather kernel (Alg. 4) in interpret mode."""
    out = run_devices(DISTKERNEL_SCRIPT, devices=8)
    assert "OK" in out
