"""Synthetic data pipeline: determinism + restart reproducibility."""
import numpy as np

from repro.data.pipeline import SyntheticTokens


def test_deterministic_across_instances():
    a = SyntheticTokens(vocab_size=100, seq_len=16, global_batch=4, seed=3)
    b = SyntheticTokens(vocab_size=100, seq_len=16, global_batch=4, seed=3)
    ta, la = a.global_batch_np(5)
    tb, lb = b.global_batch_np(5)
    np.testing.assert_array_equal(ta, tb)
    np.testing.assert_array_equal(la, lb)


def test_labels_are_shifted_tokens():
    d = SyntheticTokens(vocab_size=50, seq_len=8, global_batch=2, seed=0)
    t, l = d.global_batch_np(0)
    # labels are next-token targets of the same underlying stream
    assert t.shape == l.shape == (2, 8)
    np.testing.assert_array_equal(t[:, 1:], l[:, :-1])


def test_steps_differ():
    d = SyntheticTokens(vocab_size=1000, seq_len=32, global_batch=2, seed=0)
    t0, _ = d.global_batch_np(0)
    t1, _ = d.global_batch_np(1)
    assert (t0 != t1).any()


def test_rows_differ():
    d = SyntheticTokens(vocab_size=1000, seq_len=32, global_batch=4, seed=0)
    t, _ = d.global_batch_np(0)
    assert (t[0] != t[1]).any()


def test_tokens_in_vocab():
    d = SyntheticTokens(vocab_size=17, seq_len=64, global_batch=3, seed=9)
    t, l = d.global_batch_np(2)
    for arr in (t, l):
        assert arr.min() >= 0 and arr.max() < 17


def test_prefetch_iterator():
    d = SyntheticTokens(vocab_size=100, seq_len=8, global_batch=2, seed=0)
    it = d.iterate(start_step=3)
    step, (t, l) = next(it)
    assert step == 3
    t_direct, _ = d.global_batch_np(3)
    np.testing.assert_array_equal(np.asarray(t), t_direct)
