"""Ring-pipeline engine property tests.

1. Baseline equivalence: EVERY op in the engine registry
   (core/overlap.py), under EVERY transport it declares, must match its
   monolithic baseline numerically on world in {2, 4, 8} virtual
   devices. The script asserts its own coverage against the live
   registry, so registering a new op without extending the harness
   fails loudly.
2. Kernel-backend equivalence: every (op, transport) pair with a
   registered kernel lowering (OverlapSpec.kernel_transports) must match
   the graph backend's output — on CPU this runs the fused shmem kernels
   on the emulated-DMA backend (real put/signal/credit protocol).
3. Schedule validity: the bidir and 2-level orders in core/schedules.py
   satisfy their permutation / arrival / hand-off invariants.
"""
import textwrap

import pytest

from conftest import run_devices
from repro.core import schedules as S

SCRIPT = textwrap.dedent("""
    import functools
    import jax, jax.numpy as jnp, numpy as np
    from jax import lax
    from jax.sharding import PartitionSpec as P
    from repro.core import overlap as ov
    from repro.core import collective_matmul as cm
    from repro.core import moe_overlap as mo
    from repro.core import flash_decode as fdm
    from repro.core.ring_attention import ring_attention
    from repro.kernels import ref

    W = __WORLD__
    TOL = 2e-4
    mesh = jax.make_mesh((W,), ("tp",), axis_types=(jax.sharding.AxisType.Auto,))
    rng = np.random.RandomState(0)
    tested = set()

    def sh(fn, in_specs, out_specs):
        return jax.jit(jax.shard_map(fn, mesh=mesh, in_specs=in_specs,
                                     out_specs=out_specs, check_vma=False))

    def check(name, got, want):
        err = np.abs(np.asarray(got) - np.asarray(want)).max()
        assert err < TOL, (name, err)

    # ---------------- ag_matmul / matmul_rs (1-level) ----------------
    M, K, N = 8 * W, 16, 4 * W
    A = jnp.asarray(rng.randn(M, K), jnp.float32)
    B = jnp.asarray(rng.randn(K, N), jnp.float32)
    wantAB = np.asarray(A) @ np.asarray(B)
    for mode in ov.transports_for("ag_matmul", include_baseline=True):
        f = sh(functools.partial(cm.ag_matmul, axis="tp", mode=mode,
                                 out_dtype=jnp.float32),
               (P("tp", None), P(None, "tp")), P(None, "tp"))
        check(("ag_matmul", mode), f(A, B), wantAB)
    f = sh(functools.partial(cm.ag_matmul, axis="tp", mode="ring",
                             chunks_per_rank=2, out_dtype=jnp.float32),
           (P("tp", None), P(None, "tp")), P(None, "tp"))
    check(("ag_matmul", "ring/sub2"), f(A, B), wantAB)
    tested.add("ag_matmul")

    A2 = jnp.asarray(rng.randn(M, 8 * W), jnp.float32)
    B2 = jnp.asarray(rng.randn(8 * W, N), jnp.float32)
    want2 = np.asarray(A2) @ np.asarray(B2)
    for mode in ov.transports_for("matmul_rs", include_baseline=True):
        f = sh(functools.partial(cm.matmul_rs, axis="tp", mode=mode,
                                 out_dtype=jnp.float32),
               (P(None, "tp"), P("tp", None)), P("tp", None))
        check(("matmul_rs", mode), f(A2, B2), want2)
    # sub-chunked RS ring (the rs_chunks knob, mirroring ag_chunks)
    f = sh(functools.partial(cm.matmul_rs, axis="tp", mode="ring",
                             chunks_per_rank=2, out_dtype=jnp.float32),
           (P(None, "tp"), P("tp", None)), P("tp", None))
    check(("matmul_rs", "ring/sub2"), f(A2, B2), want2)
    tested.add("matmul_rs")

    # ---------------- 2-level ops on a (2, W//2) compound mesh -------
    wo, wi = 2, max(1, W // 2)
    mesh2 = jax.make_mesh((wo, wi), ("pod", "tp"),
                          axis_types=(jax.sharding.AxisType.Auto,) * 2)

    def sh2(fn, in_specs, out_specs):
        return jax.jit(jax.shard_map(fn, mesh=mesh2, in_specs=in_specs,
                                     out_specs=out_specs, check_vma=False))

    AG2_SPECS = ((P(("pod", "tp"), None), P(None, ("pod", "tp"))),
                 P(None, ("pod", "tp")))
    RS2_SPECS = ((P(None, ("pod", "tp")), P(("pod", "tp"), None)),
                 P(("pod", "tp"), None))
    for mode in ov.transports_for("ag_matmul_2level", include_baseline=True):
        f = sh2(functools.partial(cm.ag_matmul_2level, inner_axis="tp",
                                  outer_axis="pod", mode=mode,
                                  out_dtype=jnp.float32), *AG2_SPECS)
        check(("ag_matmul_2level", mode), f(A, B), wantAB)
    tested.add("ag_matmul_2level")

    for mode in ov.transports_for("matmul_rs_2level", include_baseline=True):
        f = sh2(functools.partial(cm.matmul_rs_2level, inner_axis="tp",
                                  outer_axis="pod", mode=mode,
                                  out_dtype=jnp.float32), *RS2_SPECS)
        check(("matmul_rs_2level", mode), f(A2, B2), want2)
    tested.add("matmul_rs_2level")

    # ---------------- stand-alone gather / reduce-scatter ------------
    x = jnp.asarray(rng.randn(8 * W, 8), jnp.float32)
    for mode in ov.transports_for("all_gather", include_baseline=True):
        f = sh(functools.partial(cm.all_gather_chunked, axis="tp", mode=mode),
               P("tp", None), P(None, None))
        check(("all_gather", mode), f(x), np.asarray(x))
    tested.add("all_gather")

    for mode in ov.transports_for("reduce_scatter", include_baseline=True):
        f = sh(functools.partial(cm.reduce_scatter_chunked, axis="tp",
                                 mode=mode),
               P(None, None), P("tp", None))
        check(("reduce_scatter", mode), f(x), W * np.asarray(x))
    tested.add("reduce_scatter")

    # ---------------- MoE: ag_moe / moe_rs (rank-dependent expert) ---
    T_loc, D, E = 8, 8, 4
    xt = jnp.asarray(rng.randn(T_loc * W, D), jnp.float32)
    lt = jnp.asarray(rng.randn(T_loc * W, E), jnp.float32)
    We = jnp.asarray(rng.randn(D, D) / np.sqrt(D), jnp.float32)
    Wl = jnp.asarray(rng.randn(E, D), jnp.float32)

    def expert(tok, lg):
        # rowwise + rank-dependent (a d_ff-shard analogue): catches both
        # row misrouting and cross-rank misalignment
        me = lax.axis_index("tp").astype(jnp.float32)
        return jnp.tanh(tok @ We) * (1.0 + me) + lg @ Wl

    def ag_moe_err(xb, lb, mode):
        got = mo.ag_moe(xb, lb, expert, "tp", mode=mode)
        want = expert(lax.all_gather(xb, "tp", tiled=True),
                      lax.all_gather(lb, "tp", tiled=True))
        return lax.pmax(jnp.abs(got - want).max(), "tp")

    for mode in ov.transports_for("ag_moe", include_baseline=True):
        f = sh(functools.partial(ag_moe_err, mode=mode),
               (P("tp", None), P("tp", None)), P())
        assert float(f(xt, lt)) < TOL, ("ag_moe", mode, float(f(xt, lt)))
    tested.add("ag_moe")

    def moe_rs_err(xf, lf, mode):
        got = mo.moe_rs(xf, lf, expert, "tp", mode=mode)
        want = lax.psum_scatter(expert(xf, lf), "tp",
                                scatter_dimension=0, tiled=True)
        return lax.pmax(jnp.abs(got - want).max(), "tp")

    for mode in ov.transports_for("moe_rs", include_baseline=True):
        f = sh(functools.partial(moe_rs_err, mode=mode),
               (P(None, None), P(None, None)), P())
        assert float(f(xt, lt)) < TOL, ("moe_rs", mode)
    tested.add("moe_rs")

    # ---------------- EP AllToAll: one_shot vs XLA baseline ----------
    Eg, cap = 2 * W, 4
    xa = jnp.asarray(rng.randn(W * Eg, cap, D), jnp.float32)

    def a2a_pair(xb, mode):
        got = mo.a2a_ep(xb, "tp", mode=mode)
        rt = mo.a2a_ep_inverse(got, "tp", mode=mode)
        base = mo.a2a_ep(xb, "tp", mode="xla")
        return (lax.pmax(jnp.abs(got - base).max(), "tp"),
                lax.pmax(jnp.abs(rt - xb).max(), "tp"))

    for mode in ov.transports_for("a2a_ep", include_baseline=True):
        f = sh(functools.partial(a2a_pair, mode=mode),
               P("tp", None, None), (P(), P()))
        d_err, rt_err = f(xa)
        assert float(d_err) == 0.0 and float(rt_err) == 0.0, ("a2a_ep", mode)
    tested.add("a2a_ep")

    # ---------------- ring attention vs full-attention oracle --------
    Bb, H, HKV, Dh = 2, 4, 2, 16
    Sq = 8 * W
    q = jnp.asarray(rng.randn(Bb, H, Sq, Dh), jnp.float32)
    kk = jnp.asarray(rng.randn(Bb, HKV, Sq, Dh), jnp.float32)
    vv = jnp.asarray(rng.randn(Bb, HKV, Sq, Dh), jnp.float32)
    ATTN_SPECS = ((P(None, None, "tp", None),) * 3, P(None, None, "tp", None))
    for causal in (True, False):
        want_attn = np.asarray(ref.flash_attention(q, kk, vv, causal=causal))
        for mode in ov.transports_for("ring_attention", include_baseline=True):
            f = sh(functools.partial(ring_attention, axis="tp", causal=causal,
                                     mode=mode), *ATTN_SPECS)
            check(("ring_attention", mode, causal), f(q, kk, vv), want_attn)
    tested.add("ring_attention")

    # ---------------- flash-decode combine vs XLA gather -------------
    qd = jnp.asarray(rng.randn(Bb, H, Dh), jnp.float32)
    kd = jnp.asarray(rng.randn(Bb, HKV, 16 * W, Dh), jnp.float32)
    vd = jnp.asarray(rng.randn(Bb, HKV, 16 * W, Dh), jnp.float32)
    lens = jnp.full((Bb,), 16 * W, jnp.int32)
    want_dec, _ = ref.flash_decode(qd, kd, vd, length=lens)

    def ddecode(q_, k_, v_, mode, backend="graph"):
        ll = jnp.full((q_.shape[0],), k_.shape[2], jnp.int32)
        return fdm.distributed_flash_decode(q_, k_, v_, ll, "tp", mode=mode,
                                            backend=backend)

    for mode in ov.transports_for("flash_decode", include_baseline=True):
        f = sh(functools.partial(ddecode, mode=mode),
               (P(None,), P(None, None, "tp", None), P(None, None, "tp", None)),
               P(None,))
        check(("flash_decode", mode), f(qd, kd, vd), np.asarray(want_dec))
    tested.add("flash_decode")

    # ---------------- fused rs->ag boundary declaration --------------
    from repro import ops as oplib

    XRf = jnp.asarray(rng.randn(M, N), jnp.float32)
    WIf = jnp.asarray(rng.randn(N, 4 * W), jnp.float32)

    def seam(r, xr):
        # rank-local row fn at the boundary (residual add + nonlinearity)
        return jnp.tanh(r + xr)

    want_f = np.tanh(np.asarray(A2) @ np.asarray(B2) + np.asarray(XRf)) \
        @ np.asarray(WIf)
    FUSED_SPECS = ((P(None, "tp"), P("tp", None), P(None, "tp"),
                    P("tp", None)), P(None, "tp"))
    for mode in ov.transports_for("matmul_rs_ag_matmul",
                                  include_baseline=True):
        f = sh(functools.partial(oplib.matmul_rs_ag_matmul, axis="tp",
                                 mode=mode, out_dtype=jnp.float32, mid=seam),
               *FUSED_SPECS)
        check(("matmul_rs_ag_matmul", mode), f(A2, B2, WIf, XRf), want_f)
    # sub-chunked boundary (the chunks knob splits the reduced block)
    f = sh(functools.partial(oplib.matmul_rs_ag_matmul, axis="tp",
                             mode="ring", chunks=2, out_dtype=jnp.float32,
                             mid=seam), *FUSED_SPECS)
    check(("matmul_rs_ag_matmul", "ring/sub2"), f(A2, B2, WIf, XRf), want_f)
    tested.add("matmul_rs_ag_matmul")

    # ---------------- kernel backend: fused shmem kernels ------------
    # Every (op, transport) the registry declares kernel-capable must
    # match the graph backend's output (the emulated-DMA backend runs
    # the real put/signal/credit protocol on CPU virtual devices).
    def run_ag(mode, backend):
        f = sh(functools.partial(cm.ag_matmul, axis="tp", mode=mode,
                                 backend=backend, out_dtype=jnp.float32),
               (P("tp", None), P(None, "tp")), P(None, "tp"))
        return np.asarray(f(A, B))

    def run_rs(mode, backend):
        f = sh(functools.partial(cm.matmul_rs, axis="tp", mode=mode,
                                 backend=backend, out_dtype=jnp.float32),
               (P(None, "tp"), P("tp", None)), P("tp", None))
        return np.asarray(f(A2, B2))

    def run_gather(mode, backend):
        f = sh(functools.partial(cm.all_gather_chunked, axis="tp", mode=mode,
                                 backend=backend),
               P("tp", None), P(None, None))
        return np.asarray(f(x))

    def run_rsc(mode, backend):
        f = sh(functools.partial(cm.reduce_scatter_chunked, axis="tp",
                                 mode=mode, backend=backend),
               P(None, None), P("tp", None))
        return np.asarray(f(x))

    def run_a2a(mode, backend):
        # both directions under one runner: the inverse reuses the same
        # registered op with transposed block placement, on a DISPATCHED
        # (capacity-grouped) tensor
        f = sh(functools.partial(mo.a2a_ep, axis="tp", mode=mode,
                                 backend=backend),
               P("tp", None, None), P("tp", None, None))
        y = f(xa)
        g = sh(lambda yy: mo.a2a_ep_inverse(yy, "tp", mode=mode,
                                            backend=backend),
               P("tp", None, None), P("tp", None, None))
        return np.concatenate([np.asarray(y).ravel(),
                               np.asarray(g(y)).ravel()])

    def run_fd(mode, backend):
        f = sh(functools.partial(ddecode, mode=mode, backend=backend),
               (P(None,), P(None, None, "tp", None), P(None, None, "tp", None)),
               P(None,))
        return np.asarray(f(qd, kd, vd))

    def run_moe_rs(mode, backend):
        f = sh(lambda xf, lf: mo.moe_rs(xf, lf, expert, "tp", mode=mode,
                                        backend=backend),
               (P(None, None), P(None, None)), P("tp", None))
        return np.asarray(f(xt, lt))

    def run_rattn(mode, backend):
        # both causal regimes under one runner: the carry-passing
        # ring_fold protocol's owner swizzle feeds the causal mask
        outs = []
        for causal in (True, False):
            f = sh(functools.partial(ring_attention, axis="tp",
                                     causal=causal, mode=mode,
                                     backend=backend), *ATTN_SPECS)
            outs.append(np.asarray(f(q, kk, vv)).ravel())
        return np.concatenate(outs)

    def run_ag2(mode, backend):
        f = sh2(functools.partial(cm.ag_matmul_2level, inner_axis="tp",
                                  outer_axis="pod", mode=mode,
                                  backend=backend, out_dtype=jnp.float32),
                *AG2_SPECS)
        return np.asarray(f(A, B))

    def run_rs2(mode, backend):
        f = sh2(functools.partial(cm.matmul_rs_2level, inner_axis="tp",
                                  outer_axis="pod", mode=mode,
                                  backend=backend, out_dtype=jnp.float32),
                *RS2_SPECS)
        return np.asarray(f(A2, B2))

    def run_fused(mode, backend):
        f = sh(functools.partial(oplib.matmul_rs_ag_matmul, axis="tp",
                                 mode=mode, backend=backend,
                                 out_dtype=jnp.float32, mid=seam),
               *FUSED_SPECS)
        return np.asarray(f(A2, B2, WIf, XRf))

    kernel_runners = {"ag_matmul": run_ag, "matmul_rs": run_rs,
                      "all_gather": run_gather, "reduce_scatter": run_rsc,
                      "a2a_ep": run_a2a, "flash_decode": run_fd,
                      "moe_rs": run_moe_rs, "ring_attention": run_rattn,
                      "ag_matmul_2level": run_ag2,
                      "matmul_rs_2level": run_rs2,
                      "matmul_rs_ag_matmul": run_fused}
    kernel_pairs = [(nm, t) for nm, spec in ov.registry().items()
                    for t in spec.kernel_transports]
    assert kernel_pairs, "no kernel-capable (op, transport) pairs registered"
    for nm, t in kernel_pairs:
        if nm == "ag_moe":
            continue  # rank-dependent output: compared in-program below
        assert nm in kernel_runners, \
            f"kernel transport {nm}/{t} without a harness"
        got_k = kernel_runners[nm](t, "kernel")
        got_g = kernel_runners[nm](t, "graph")
        if nm in ("a2a_ep", "all_gather", "flash_decode"):
            # pure data movement: BIT-identical across backends
            assert np.array_equal(got_k, got_g), ("kernel-vs-graph", nm, t)
        else:
            err = np.abs(got_k - got_g).max()
            assert err < TOL, ("kernel-vs-graph", nm, t, err)
    # ag_moe's per-rank outputs differ by design (rank-dependent expert):
    # kernel-vs-graph is compared inside the SPMD program
    def agmoe_kernel_err(xb, lb, mode):
        got_k = mo.ag_moe(xb, lb, expert, "tp", mode=mode, backend="kernel")
        got_g = mo.ag_moe(xb, lb, expert, "tp", mode=mode, backend="graph")
        return lax.pmax(jnp.abs(got_k - got_g).max(), "tp")

    for mode in ov.get("ag_moe").kernel_transports:
        f = sh(functools.partial(agmoe_kernel_err, mode=mode),
               (P("tp", None), P("tp", None)), P())
        assert float(f(xt, lt)) < TOL, ("ag_moe kernel", mode)

    # mixed precision (bf16 tokens + f32 router logits): the packed
    # riding chunk must promote, not round — kernel == graph exactly
    # (exact pack/unpack casts; moe_rs partials ride and reduce in f32)
    xt16 = xt.astype(jnp.bfloat16)

    def expert16(tok, lg):
        assert tok.dtype == jnp.bfloat16 and lg.dtype == jnp.float32
        me = lax.axis_index("tp").astype(jnp.float32)
        t32 = tok.astype(jnp.float32)
        return jnp.tanh(t32 @ We) * (1.0 + me) + lg @ Wl

    def moe_rs16(xf, lf, backend):
        return mo.moe_rs(xf, lf, expert16, "tp", mode="ring",
                         backend=backend).astype(jnp.float32)

    k16 = np.asarray(sh(functools.partial(moe_rs16, backend="kernel"),
                        (P(None, None), P(None, None)), P("tp", None))(xt16, lt))
    g16 = np.asarray(sh(functools.partial(moe_rs16, backend="graph"),
                        (P(None, None), P(None, None)), P("tp", None))(xt16, lt))
    assert np.array_equal(k16, g16), "moe_rs mixed-precision kernel parity"

    def agmoe16_err(xb, lb):
        got_k = mo.ag_moe(xb, lb, expert16, "tp", mode="ring",
                          backend="kernel")
        got_g = mo.ag_moe(xb, lb, expert16, "tp", mode="ring",
                          backend="graph")
        return lax.pmax(jnp.abs(got_k - got_g).max(), "tp")

    assert float(sh(agmoe16_err, (P("tp", None), P("tp", None)),
                    P())(xt16, lt)) == 0.0, "ag_moe mixed-precision parity"
    # requesting kernel where no kernel lowering exists degrades to graph
    check(("matmul_rs", "bidir", "kernel->graph"),
          run_rs("bidir", "kernel"), want2)

    # grads are BIT-identical across backends (the kernel forward keeps
    # the graph-lowered dual as its backward through the ONE custom_vjp)
    def a2a_grad(backend):
        def loss(xb):
            out = mo.a2a_ep(xb, "tp", mode="one_shot", backend=backend)
            return lax.psum(jnp.sum(out * out), "tp")
        return np.asarray(sh(jax.grad(loss), P("tp", None, None),
                             P("tp", None, None))(xa))

    assert np.array_equal(a2a_grad("graph"), a2a_grad("kernel")), "a2a grads"

    packed = jnp.asarray(rng.randn(Bb, H, Dh + 1), jnp.float32)

    def fd_grad(backend):
        def loss(p):
            out = ov.dispatch("flash_decode", p, axis="tp", mode="one_shot",
                              backend=backend)
            return lax.psum(jnp.sum(out * out), "tp")
        return np.asarray(sh(jax.grad(loss), P(None, None, None),
                             P(None, None, None))(packed))

    assert np.array_equal(fd_grad("graph"), fd_grad("kernel")), "fd grads"

    def bidir_ag_grads(backend):
        def loss(a, b):
            out = cm.ag_matmul(a, b, "tp", mode="bidir", backend=backend,
                               out_dtype=jnp.float32)
            return lax.psum(jnp.sum(out * out), "tp")
        return [np.asarray(t) for t in
                sh(jax.grad(loss, argnums=(0, 1)),
                   (P("tp", None), P(None, "tp")),
                   (P("tp", None), P(None, "tp")))(A, B)]

    for a, b in zip(bidir_ag_grads("graph"), bidir_ag_grads("kernel")):
        assert np.array_equal(a, b), "bidir ag_matmul grads differ"

    # ring attention: grads BIT-identical across backends (the kernel's
    # ring_fold forward keeps the jax.vjp-through-the-fold-chain graph
    # dual through the ONE custom_vjp), causal AND non-causal — and the
    # ring forward is bit-equal too (same fold order, same f32 ops).
    def rattn_grads(backend, causal):
        def loss(q_, k_, v_):
            out = ring_attention(q_, k_, v_, "tp", causal=causal,
                                 mode="ring", backend=backend)
            return lax.psum(jnp.sum(out * out), "tp")
        return [np.asarray(t) for t in
                sh(jax.grad(loss, argnums=(0, 1, 2)),
                   ATTN_SPECS[0], (P(None, None, "tp", None),) * 3)(q, kk, vv)]

    for causal in (True, False):
        for a, b in zip(rattn_grads("graph", causal),
                        rattn_grads("kernel", causal)):
            assert np.array_equal(a, b), ("ring_attention grads", causal)

    # 2-level grads bit-identical across backends too
    def ag2_grads(backend):
        def loss(a, b):
            out = cm.ag_matmul_2level(a, b, "tp", "pod", backend=backend,
                                      out_dtype=jnp.float32)
            return lax.psum(jnp.sum(out * out), ("pod", "tp"))
        return [np.asarray(t) for t in
                sh2(jax.grad(loss, argnums=(0, 1)), AG2_SPECS[0],
                    AG2_SPECS[0])(A, B)]

    for a, b in zip(ag2_grads("graph"), ag2_grads("kernel")):
        assert np.array_equal(a, b), "ag_matmul_2level grads differ"

    # ---------------- coverage: no registered op left untested -------
    missing = set(ov.registry()) - tested
    assert not missing, f"registry ops without a baseline test: {missing}"
    print("OK", sorted(tested))
""")


@pytest.mark.parametrize("world", [2, 4, 8])
def test_registry_pipelines_match_baselines(world):
    out = run_devices(SCRIPT.replace("__WORLD__", str(world)), devices=world,
                      timeout=1200)
    assert "OK" in out


# ---------------------------------------------------------------------------
# Schedule validity for the bidir and 2-level orders
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("world", [3, 4, 8, 16, 17])
def test_bidir_ag_schedule_valid(world):
    assert S.validate_bidir_ag(world)


@pytest.mark.parametrize("world", [3, 4, 8, 16, 17])
def test_bidir_rs_schedule_valid(world):
    assert S.validate_bidir_rs(world)


@pytest.mark.parametrize("no,ni", [(2, 2), (2, 4), (4, 4), (3, 5)])
def test_two_level_schedules_valid(no, ni):
    assert S.validate_two_level_ag(no, ni)
    assert S.validate_two_level_rs(no, ni)


def test_registry_declares_known_transports_only():
    from repro.core import overlap as ov

    for name, spec in ov.registry().items():
        assert spec.transports, name
        for t in spec.transports:
            assert t in ov.TRANSPORTS, (name, t)
        assert spec.default in spec.transports, name
        # resolving an unsupported request falls back to the default
        assert ov.resolve_mode(name, "definitely-not-a-mode") == spec.default


def test_registry_backend_resolution():
    import pytest

    from repro.core import overlap as ov

    for name, spec in ov.registry().items():
        # kernel transports are a subset of the op's transports and come
        # paired with a kernel lowering
        for t in spec.kernel_transports:
            assert t in spec.transports, (name, t)
        assert bool(spec.kernel_transports) == (spec.kernel_fwd is not None)
        assert ov.backends_for(name)[0] == "graph"
        # graph always resolves; kernel resolves only for kernel pairs
        assert ov.resolve_backend(name, "graph") == "graph"
        for t in spec.transports:
            want = "kernel" if t in spec.kernel_transports else "graph"
            assert ov.resolve_backend(name, "kernel", t) == want, (name, t)
        # the baseline mode never lowers through the kernel backend
        assert ov.resolve_backend(name, "kernel", spec.baseline) == "graph"
    with pytest.raises(ValueError):
        ov.resolve_backend("ag_matmul", "definitely-not-a-backend")


def test_every_registry_op_is_dispatch_routed_and_kernel_capable():
    """No graph-only OR fwd-less escape hatches left: EVERY op in the
    engine registry routes through ``overlap.dispatch`` (a registered
    ``fwd``) and has a kernel lowering — including ring attention (the
    carry-passing ``ring_fold`` protocol) and the 2-level compound-mesh
    ops (the two-axis ``two_level_ag``/``two_level_rs`` protocols). The
    backend axis covers the whole registry."""
    from repro.core import overlap as ov

    registry = ov.registry()
    assert set(registry) >= {"ag_matmul", "matmul_rs", "all_gather",
                             "reduce_scatter", "a2a_ep", "flash_decode",
                             "ag_moe", "moe_rs", "ring_attention",
                             "ag_matmul_2level", "matmul_rs_2level"}
    for name, spec in registry.items():
        assert spec.fwd is not None, f"{name} is not dispatch-routed"
        assert ov.backends_for(name) == ("graph", "kernel"), name
    # this PR's named bindings, specifically
    assert ov.get("ring_attention").kernel_transports == ("ring", "one_shot")
    assert ov.get("ag_matmul_2level").kernel_transports == ("two_level",)
    assert ov.get("matmul_rs_2level").kernel_transports == ("two_level",)
    # the fused boundary declaration is registry-routed too: its kernel
    # transport binds the chained push_rs -> ring_ag protocol
    assert ov.get("matmul_rs_ag_matmul").kernel_transports == ("ring",)
    # earlier PRs' bindings stay
    assert "one_shot" in ov.get("a2a_ep").kernel_transports
    assert "one_shot" in ov.get("flash_decode").kernel_transports
    assert "bidir" in ov.get("ag_matmul").kernel_transports
    # ...and the fold ops differentiate: the kernel forward keeps the
    # jax.vjp-through-the-fold-chain dual via the shared custom_vjp
    assert ov.get("ring_attention").bwd is not None
    assert ov.get("ag_moe").bwd is not None and ov.get("moe_rs").bwd is not None


_SCAN_KERNEL_TRAIN = textwrap.dedent("""
    import functools
    import jax, jax.numpy as jnp, numpy as np
    from jax import lax
    from jax.sharding import PartitionSpec as P
    from repro import ops

    W = 2
    mesh = jax.make_mesh((W,), ("tp",), axis_types=(jax.sharding.AxisType.Auto,))
    rng = np.random.RandomState(0)
    A = jnp.asarray(rng.randn(4 * W, 8), jnp.float32)
    Wt = jnp.asarray(rng.randn(8, 2 * W), jnp.float32)

    def loss(a, w):
        # a 2-"layer" scan over the kernel-backend op: the whole-model
        # training shape (layers scanned, overlapped op inside)
        def layer(carry, _):
            y = ops.ag_matmul(carry, w, axis="tp", mode="ring",
                              backend="kernel", out_dtype=jnp.float32)
            return carry, jnp.sum(y * y)
        _, ys = lax.scan(layer, a, jnp.arange(2))
        return lax.psum(jnp.sum(ys), "tp")

    g = jax.jit(jax.shard_map(jax.grad(loss, argnums=(0, 1)), mesh=mesh,
                              in_specs=(P("tp", None), P(None, "tp")),
                              out_specs=(P("tp", None), P(None, "tp")),
                              check_vma=False))(A, Wt)
    jax.block_until_ready(g)
    print("OK scan kernel train")
""")


@pytest.mark.xfail(
    strict=True, raises=RuntimeError,
    reason="jax CPU-emulation limit: io_callback effects inside the shared "
           "custom_vjp are rejected under lax.scan ('Effects not supported "
           "in custom_vjp'); the pltpu lowering carries no IOEffect, so "
           "this is emulated-backend-only. A jax-side fix flips this "
           "loudly (strict XPASS).")
def test_kernel_backend_training_under_scan_hits_custom_vjp_effects_limit():
    """Kernel-backend TRAINING under ``lax.scan`` on CPU: pins the exact
    known-failure message so the emulation limit is visible. Any other
    failure mode is a REAL failure (the AssertionError is re-raised and
    not matched by ``raises=RuntimeError``)."""
    try:
        out = run_devices(_SCAN_KERNEL_TRAIN, devices=2)
    except AssertionError as e:
        # jax spells it "Effects not supported in `custom_vjp`"
        if "Effects not supported in" in str(e) and "custom_vjp" in str(e):
            raise RuntimeError(
                "known jax limit: Effects not supported in custom_vjp"
            ) from e
        raise
    assert "OK scan kernel train" in out
