"""Pallas kernel vs. pure-jnp oracle allclose sweeps (shapes x dtypes).

Single-device: kernels run in interpret mode (pl.pallas_call on CPU)."""
import os
import sys
sys.path.insert(0, os.path.dirname(__file__))

import jax.numpy as jnp
import numpy as np
import pytest

import proptest as pt
from repro.kernels import ops, ref

R = np.random.RandomState(42)


def _arr(shape, dtype=jnp.float32, scale=1.0):
    return jnp.asarray(R.randn(*shape) * scale, dtype)


# ---------------------------------------------------------------- matmul
@pytest.mark.parametrize("m,k,n", [(64, 64, 64), (128, 256, 64), (96, 200, 130),
                                   (256, 64, 512)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul_sweep(m, k, n, dtype):
    a, b = _arr((m, k), dtype), _arr((k, n), dtype)
    got = ops.matmul(a, b, force="pallas", bm=64, bk=64, bn=64)
    want = ref.matmul(a, b)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=tol, rtol=tol)


@pytest.mark.parametrize("rank,world", [(0, 4), (2, 4), (3, 4), (1, 2)])
def test_matmul_swizzled_grid(rank, world):
    a, b = _arr((256, 64)), _arr((64, 64))
    got = ops.matmul(a, b, force="pallas", bm=32, bk=64, bn=64,
                     rank=rank, world=world)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref.matmul(a, b)),
                               atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------- grouped matmul
@pytest.mark.parametrize("e,cap,k,n", [(4, 64, 96, 80), (8, 32, 64, 64),
                                       (2, 128, 48, 96)])
def test_grouped_matmul_sweep(e, cap, k, n):
    x, w = _arr((e, cap, k)), _arr((e, k, n))
    got = ops.grouped_matmul(x, w, force="pallas", bm=32, bk=32, bn=32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref.grouped_matmul(x, w)),
                               atol=2e-5, rtol=2e-5)


# --------------------------------------------------------- flash attention
@pytest.mark.parametrize("hq,hkv", [(4, 4), (4, 2), (8, 1)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(hq, hkv, causal):
    q = _arr((2, hq, 128, 32))
    k = _arr((2, hkv, 128, 32))
    v = _arr((2, hkv, 128, 32))
    got = ops.flash_attention(q, k, v, causal=causal, force="pallas", bq=32, bkv=32)
    want = ref.flash_attention(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5, rtol=2e-4)


def test_flash_attention_bf16():
    q = _arr((1, 2, 64, 32), jnp.bfloat16)
    k = _arr((1, 2, 64, 32), jnp.bfloat16)
    v = _arr((1, 2, 64, 32), jnp.bfloat16)
    got = ops.flash_attention(q, k, v, force="pallas", bq=32, bkv=32)
    want = ref.flash_attention(q, k, v)
    np.testing.assert_allclose(np.asarray(got, np.float32), np.asarray(want, np.float32),
                               atol=3e-2, rtol=3e-2)


def test_flash_attention_chunked_matches_plain():
    q, k, v = _arr((2, 4, 128, 32)), _arr((2, 2, 128, 32)), _arr((2, 2, 128, 32))
    for causal in (True, False):
        a = ref.flash_attention(q, k, v, causal=causal)
        b = ref.flash_attention_chunked(q, k, v, causal=causal, kv_chunk=32)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5, rtol=2e-5)


# ------------------------------------------------------------ flash decode
@pt.given(examples=8, s=pt.sampled_from([64, 128, 256]),
          hq=pt.sampled_from([2, 4]), hkv=pt.sampled_from([1, 2]))
def test_flash_decode_sweep(s, hq, hkv):
    b, d = 2, 32
    q = _arr((b, hq, d))
    k = _arr((b, hkv, s, d))
    v = _arr((b, hkv, s, d))
    lens = jnp.asarray([s, s // 2], jnp.int32)
    og, lg = ops.flash_decode(q, k, v, lens, force="pallas", bkv=32)
    ow, lw = ref.flash_decode(q, k, v, length=lens)
    np.testing.assert_allclose(np.asarray(og), np.asarray(ow), atol=2e-5, rtol=2e-4)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(lw), atol=2e-5, rtol=2e-5)


# --------------------------------------------------------------- ssd scan
@pt.given(examples=6, l=pt.sampled_from([32, 64]), h=pt.sampled_from([2, 4]),
          g=pt.sampled_from([1, 2]), chunk=pt.sampled_from([8, 16, 32]))
def test_ssd_scan_sweep(l, h, g, chunk):
    if h % g != 0:
        g = 1
    b, p, s = 2, 16, 16
    x = _arr((b, l, h, p), scale=0.5)
    dt = jnp.asarray(R.rand(b, l, h) * 0.5 + 0.01, jnp.float32)
    a = jnp.asarray(-np.abs(R.rand(h)) - 0.1, jnp.float32)
    bm = _arr((b, l, g, s), scale=0.3)
    cm = _arr((b, l, g, s), scale=0.3)
    yg, sg = ops.ssd_scan(x, dt, a, bm, cm, chunk=chunk, force="pallas")
    yw, sw = ref.ssd_scan(x, dt, a, bm, cm)
    np.testing.assert_allclose(np.asarray(yg), np.asarray(yw), atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(sg), np.asarray(sw), atol=1e-4, rtol=1e-4)


@pt.given(examples=6, l=pt.sampled_from([32, 64]), chunk=pt.sampled_from([8, 16]))
def test_ssd_chunked_matches_sequential(l, chunk):
    """The chunked closed form (production XLA path) == per-step scan."""
    b, h, p, g, s = 2, 4, 16, 2, 16
    x = _arr((b, l, h, p), scale=0.5)
    dt = jnp.asarray(R.rand(b, l, h) * 0.5 + 0.01, jnp.float32)
    a = jnp.asarray(-np.abs(R.rand(h)) - 0.1, jnp.float32)
    bm = _arr((b, l, g, s), scale=0.3)
    cm = _arr((b, l, g, s), scale=0.3)
    y1, s1 = ref.ssd_scan(x, dt, a, bm, cm)
    y2, s2 = ref.ssd_scan_chunked(x, dt, a, bm, cm, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=1e-4, rtol=1e-4)


def test_ssd_scan_state_continuity():
    """Scanning two halves with carried state == scanning the whole."""
    b, l, h, p, g, s = 1, 64, 2, 16, 1, 16
    x = _arr((b, l, h, p), scale=0.5)
    dt = jnp.asarray(R.rand(b, l, h) * 0.3 + 0.01, jnp.float32)
    a = jnp.asarray(-np.abs(R.rand(h)) - 0.1, jnp.float32)
    bm = _arr((b, l, g, s), scale=0.3)
    cm = _arr((b, l, g, s), scale=0.3)
    y_full, s_full = ref.ssd_scan(x, dt, a, bm, cm)
    y1, s1 = ref.ssd_scan(x[:, :32], dt[:, :32], a, bm[:, :32], cm[:, :32])
    y2, s2 = ref.ssd_scan(x[:, 32:], dt[:, 32:], a, bm[:, 32:], cm[:, 32:],
                          init_state=s1)
    np.testing.assert_allclose(np.asarray(y_full[:, 32:]), np.asarray(y2),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(s_full), np.asarray(s2), atol=1e-4, rtol=1e-4)


# ------------------------------------------------- decode combine property
@pt.given(examples=10, w=pt.sampled_from([2, 4, 8, 16]))
def test_combine_flash_decode_partition_invariance(w):
    """Splitting KV into W shards and combining == direct attention."""
    b, h, s, d = 2, 2, 64, 16
    q = _arr((b, h, d))
    k = _arr((b, h, s, d))
    v = _arr((b, h, s, d))
    full_o, _ = ref.flash_decode(q, k, v)
    assert s % w == 0
    chunk = s // w
    os_, ls_ = [], []
    for i in range(w):
        o, l = ref.flash_decode(q, k[:, :, i * chunk:(i + 1) * chunk],
                                v[:, :, i * chunk:(i + 1) * chunk])
        os_.append(o)
        ls_.append(l)
    got = ref.combine_flash_decode(jnp.stack(os_), jnp.stack(ls_))
    np.testing.assert_allclose(np.asarray(got), np.asarray(full_o), atol=1e-5, rtol=1e-4)
