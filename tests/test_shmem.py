"""Parity tests for the repro.shmem subsystem on the emulated-DMA
backend: every primitive, on worlds 2 / 4 / 8 of virtual CPU devices
(subprocess — the main pytest process keeps 1 device).

Each sub-test uses its own collective_id and opens/closes with
barrier_all, per the backend's protocol rules; signal accounting is
exact (a timeout in any wait fails the subprocess loudly)."""
import textwrap

import pytest

from conftest import run_devices

SCRIPT = textwrap.dedent("""
    import jax, jax.numpy as jnp, numpy as np
    from jax import lax
    from jax.sharding import PartitionSpec as P
    from repro import shmem
    from repro.shmem import emulated as em

    W = __WORLD__
    mesh = jax.make_mesh((W,), ("x",), axis_types=(jax.sharding.AxisType.Auto,))

    assert shmem.default_backend() == "emulated"  # CPU host

    def sh(fn, in_specs, out_specs):
        return jax.jit(jax.shard_map(fn, mesh=mesh, in_specs=in_specs,
                                     out_specs=out_specs, check_vma=False))

    x = jnp.arange(W * 8, dtype=jnp.float32).reshape(W, 8)

    # ---- putmem_signal_nbi + wait_read: ring rotate by one ----
    def rotate(xb):
        ctx = em.ShmemCtx("x", W, cid=101)
        me = lax.axis_index("x")
        ctx.barrier_all()
        ctx.putmem_signal_nbi(xb, lax.rem(me + 1, W), buf="rot", sig="recv")
        out = ctx.wait_read(xb.shape, xb.dtype, buf="rot", sig="recv")
        ctx.barrier_all()
        return out

    got = np.asarray(sh(rotate, P("x", None), P("x", None))(x))
    want = np.roll(np.asarray(x), 1, axis=0)  # rank r's data lands at r+1
    assert np.abs(got - want).max() == 0, got
    # replay safety: signal state must be back at zero after the run
    got2 = np.asarray(sh(rotate, P("x", None), P("x", None))(x))
    assert np.abs(got2 - want).max() == 0, got2

    # ---- signal_op / signal_wait_until: counting semantics ----
    def signals(xb):
        ctx = em.ShmemCtx("x", W, cid=102)
        me = lax.axis_index("x")
        ctx.barrier_all()
        for off in range(1, W):
            ctx.signal_op(lax.rem(me + off, W), sig="s", inc=3)
        # 3 * (W-1) increments must arrive; a miscount deadlocks (timeout)
        ctx.signal_wait_until(sig="s", value=3 * (W - 1))
        ctx.barrier_all()
        return xb

    np.asarray(sh(signals, P("x", None), P("x", None))(x))

    # ---- barrier_all: makes unsignaled puts globally visible ----
    def barrier_vis(xb):
        ctx = em.ShmemCtx("x", W, cid=103)
        me = lax.axis_index("x")
        ctx.barrier_all()
        ctx.putmem_signal_nbi(2.0 * xb, lax.rem(me + 1, W), buf="b", sig="arr")
        ctx.barrier_all()  # all puts complete before anyone proceeds
        out = ctx.read_symmetric(xb.shape, xb.dtype, buf="b")
        ctx.signal_wait_until(sig="arr", value=1)  # drain to zero
        ctx.barrier_all()
        return out

    got = np.asarray(sh(barrier_vis, P("x", None), P("x", None))(x))
    assert np.abs(got - 2.0 * want).max() == 0, got

    # ---- broadcast_put (multimem_st analogue): distinct payloads ----
    def bcast(xb):
        ctx = em.ShmemCtx("x", W, cid=104)
        ctx.barrier_all()
        ctx.broadcast_put(xb, buf="bc", sig="recv")
        ctx.signal_wait_until(sig="recv", value=W)
        out = jnp.zeros((W,) + xb.shape, xb.dtype)
        for r in range(W):
            shard = ctx.read_symmetric(xb.shape, xb.dtype, buf="bc", slot=r)
            out = lax.dynamic_update_slice(out, shard[None],
                                           (r,) + (0,) * xb.ndim)
        ctx.barrier_all()
        return out

    got = np.asarray(sh(bcast, P("x", None), P(None, None, None))(x))
    # every rank assembled every peer's (distinct) shard, slot = sender
    assert np.abs(got.reshape(W, -1) - np.asarray(x)).max() == 0, got

    # ---- symmetric_alloc: zeroed named buffer on every PE ----
    def alloc(xb):
        ctx = em.ShmemCtx("x", W, cid=105)
        ctx.symmetric_alloc(xb.shape, xb.dtype, buf="heap")
        ctx.barrier_all()  # OpenSHMEM: barrier after allocation
        out = ctx.read_symmetric(xb.shape, xb.dtype, buf="heap")
        ctx.barrier_all()
        return out

    got = np.asarray(sh(alloc, P("x", None), P("x", None))(x))
    assert np.abs(got).max() == 0, got

    print("OK")
""")


@pytest.mark.parametrize("world", [2, 4, 8])
def test_emulated_primitives_parity(world):
    out = run_devices(SCRIPT.replace("__WORLD__", str(world)), devices=world)
    assert "OK" in out


EXECUTOR_SCRIPT = textwrap.dedent("""
    import functools
    import jax, jax.numpy as jnp, numpy as np
    from jax import lax
    from jax.sharding import PartitionSpec as P
    from repro.shmem import executor

    W = __WORLD__
    mesh = jax.make_mesh((W,), ("x",), axis_types=(jax.sharding.AxisType.Auto,))
    rng = np.random.RandomState(0)

    def sh(fn, in_specs, out_specs):
        return jax.jit(jax.shard_map(fn, mesh=mesh, in_specs=in_specs,
                                     out_specs=out_specs, check_vma=False))

    # ---- one_shot_a2a: out[src] = tile(block src sent here) ----
    xs = jnp.arange(W * W * 4, dtype=jnp.float32).reshape(W * W, 4)

    def a2a(xb):
        blocks = xb.reshape(W, xb.shape[0] // W, xb.shape[1])
        out = executor.run("one_shot_a2a", lambda b: 2.0 * b, blocks,
                           axis="x", world=W, collective_id=201)
        return out.reshape(xb.shape)

    got = np.asarray(sh(a2a, P("x", None), P("x", None))(xs))
    want = 2.0 * np.asarray(
        jax.jit(jax.shard_map(
            lambda xb: lax.all_to_all(
                xb.reshape(W, xb.shape[0] // W, xb.shape[1]),
                "x", split_axis=0, concat_axis=0, tiled=False
            ).reshape(xb.shape),
            mesh=mesh, in_specs=P("x", None), out_specs=P("x", None),
            check_vma=False))(xs))
    assert np.abs(got - want).max() == 0, (got, want)

    # ---- bidir_ring_ag: halves ride opposite rings; dot tile ----
    m_loc, K, N = 4, 8, 8
    A = jnp.asarray(rng.randn(W * m_loc, K), jnp.float32)
    B = jnp.asarray(rng.randn(K, N), jnp.float32)

    def bidir(a_blk, b):
        return executor.run(
            "bidir_ring_ag",
            lambda c, w: jnp.dot(c, w, preferred_element_type=jnp.float32),
            a_blk, (b,), axis="x", world=W, out_dtype=jnp.float32,
            collective_id=202)

    got = np.asarray(sh(bidir, (P("x", None), P(None, None)),
                        P(None, None))(A, B))
    want = np.asarray(A) @ np.asarray(B)
    assert np.abs(got - want).max() < 2e-4, np.abs(got - want).max()

    # ---- ring_fold: owner-weighted running sum carried as state ----
    ft = executor.FoldTile(
        init=lambda c: jnp.zeros(c.shape, jnp.float32),
        fold=lambda st, c, owner: st + (owner.astype(jnp.float32) + 1.0) * c,
        finalize=lambda st: st)

    def rfold(xb):
        return executor.run("ring_fold", ft, xb, axis="x", world=W,
                            out_dtype=jnp.float32, collective_id=203)

    xs2 = jnp.asarray(rng.randn(W * m_loc, K), jnp.float32)
    got = np.asarray(sh(rfold, P("x", None), P(None, None))(xs2))
    want = sum((r + 1.0) * np.asarray(xs2)[r * m_loc:(r + 1) * m_loc]
               for r in range(W))
    assert np.abs(got - want).max() < 1e-4, np.abs(got - want).max()

    # ---- two-axis protocols on a (2, W//2) pod x ring grid ----
    wo, wi = 2, max(1, W // 2)
    mesh2 = jax.make_mesh((wo, wi), ("pod", "x"),
                          axis_types=(jax.sharding.AxisType.Auto,) * 2)

    def sh2(fn, in_specs, out_specs):
        return jax.jit(jax.shard_map(fn, mesh=mesh2, in_specs=in_specs,
                                     out_specs=out_specs, check_vma=False))

    def tl_ag(a_blk, b):
        return executor.run(
            "two_level_ag",
            lambda c, w: jnp.dot(c, w, preferred_element_type=jnp.float32),
            a_blk, (b,), axis=("x", "pod"), world=(wi, wo),
            out_dtype=jnp.float32, collective_id=204)

    got = np.asarray(sh2(tl_ag, (P(("pod", "x"), None), P(None, None)),
                         P(None, None))(A, B))
    assert np.abs(got - np.asarray(A) @ np.asarray(B)).max() < 2e-4

    def tl_rs(xb):
        # replicated operand, f32-cast tile: my linearized block, W-summed
        return executor.run("two_level_rs", lambda b: b.astype(jnp.float32),
                            xb, axis=("x", "pod"), world=(wi, wo),
                            out_dtype=jnp.float32, collective_id=205)

    xr = jnp.asarray(rng.randn(W * 2, K), jnp.float32)
    got = np.asarray(sh2(tl_rs, P(None, None), P(("pod", "x"), None))(xr))
    assert np.abs(got - W * np.asarray(xr)).max() < 1e-4
    print("OK executor", W)
""")


@pytest.mark.parametrize("world", [2, 4, 8])
def test_executor_new_protocols(world):
    """The PR-4/PR-5 executor protocols, exercised directly (below the
    ops layer): one_shot_a2a vs lax.all_to_all, bidir_ring_ag vs the
    plain gathered matmul (incl. the W=2 ring degrade), the ring_fold
    carry-passing ring (owner-dependent fold state), and the two-axis
    two_level_ag / two_level_rs protocols on a (2, W//2) pod grid."""
    out = run_devices(EXECUTOR_SCRIPT.replace("__WORLD__", str(world)),
                      devices=world)
    assert "OK executor" in out


def test_rank_identity_linearization():
    """my_pe / n_pes over compound axes (graph-level, any backend)."""
    out = run_devices(textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro import shmem

        mesh2 = jax.make_mesh((2, 2), ("a", "b"),
                              axis_types=(jax.sharding.AxisType.Auto,) * 2)
        def pe(x):
            return (shmem.my_pe(("a", "b")) + shmem.n_pes(("a", "b")) * 0
                    + x[0] * 0).reshape(1)
        h = jax.jit(jax.shard_map(pe, mesh=mesh2, in_specs=P(("a", "b")),
                                  out_specs=P(("a", "b")), check_vma=False))
        ids = np.asarray(h(jnp.zeros((4,), jnp.int32)))
        assert sorted(ids.tolist()) == [0, 1, 2, 3], ids
        print("OK")
    """), devices=4)
    assert "OK" in out


def test_default_backend_and_reexports():
    """CPU hosts emulate; core.primitives keeps the Table-1 surface."""
    from repro import shmem
    from repro.core import primitives as prim

    assert shmem.default_backend() == "emulated"
    # the paper's Table-1 names remain importable from core.primitives
    for name in ("my_pe", "n_pes", "putmem_signal_nbi", "putmem_signal",
                 "signal_op", "notify", "signal_wait_until", "wait",
                 "barrier_all", "broadcast_put", "quiet", "consume_token",
                 "local_copy_nbi"):
        assert hasattr(prim, name), name
    # the emulated backend exposes the same set as ShmemCtx methods
    for name in ("putmem_signal_nbi", "putmem_signal", "signal_op",
                 "notify", "signal_wait_until", "wait", "barrier_all",
                 "broadcast_put", "read_symmetric", "wait_read",
                 "symmetric_alloc"):
        assert hasattr(shmem.emulated.ShmemCtx, name), name


def test_emulated_reset_clears_state():
    from repro.shmem import emulated as em

    # state is keyed by (collective_id, traced-kernel instance)
    w = em._world((999, 1))
    w.sems[("s", 0)] = 3
    em.reset(999)  # clears every instance of collective_id 999
    assert ("s", 0) not in em._world((999, 1)).sems
    em.reset()


def test_emulated_instances_are_private():
    """Two ShmemCtx constructions (= two traced kernels) never share
    heap/signal state, even with the same collective_id — the review
    hazard of same-cid kernels interleaving in one program."""
    from repro.shmem import emulated as em

    i0 = next(em._instances)
    a = em.ShmemCtx.__new__(em.ShmemCtx)  # avoid tracing: only check keys
    b = em.ShmemCtx.__new__(em.ShmemCtx)
    a._key = (7, i0 + 1)
    b._key = (7, i0 + 2)
    assert em._world(a._key) is not em._world(b._key)
    em.reset(7)
