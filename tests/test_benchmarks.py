"""Benchmark-harness regression tests (subprocess: the benches need >1
virtual device).

The key guard: ``bench_a2a``'s ``a2a_combine`` rows must time the
inverse path on a *dispatched* tensor — the capacity-grouped
(E_global, W*cap, d) shape — not on the raw dispatch input (the PR-3
fix; a regression would silently re-time the forward path)."""
import textwrap

import pytest

from conftest import run_devices

A2A_SCRIPT = textwrap.dedent("""
    import numpy as np
    import jax
    from benchmarks import bench_a2a

    w = min(8, jax.device_count())
    calls = []

    def fake_time_fn(fn, *args, **kw):
        calls.append(tuple(np.asarray(a).shape for a in args))
        jax.block_until_ready(fn(*args))  # still execute once: shapes real
        return 1.0

    bench_a2a.time_fn = fake_time_fn
    names = [line.split(",")[0] for line in bench_a2a.rows()]
    assert len(calls) == len(names), (len(calls), len(names))
    n_combine = 0
    for name, shapes in zip(names, calls):
        shape_tag = name.split("/")[1]            # e.g. "E16c32d128"
        e_glob, rest = shape_tag[1:].split("c")
        cap, d = rest.split("d")
        e_glob, cap, d = int(e_glob), int(cap), int(d)
        if name.startswith("a2a_dispatch"):
            assert shapes[0] == (w * e_glob, cap, d), (name, shapes)
        else:
            assert name.startswith("a2a_combine"), name
            # the inverse is timed on the DISPATCHED tensor: the
            # capacity-grouped (E_global, W*cap, d) global shape
            assert shapes[0] == (e_glob, w * cap, d), (name, shapes)
            n_combine += 1
    assert n_combine >= 3, names
    # the backend axis is present: at least one kernel-lowered row pair
    assert any(n.endswith("/kernel") for n in names), names
    print("OK bench_a2a", n_combine)
""")


@pytest.mark.parametrize("devices", [4])
def test_bench_a2a_combine_times_dispatched_tensor(devices):
    out = run_devices(A2A_SCRIPT, devices=devices, timeout=900)
    assert "OK bench_a2a" in out
