"""Benchmark-harness regression tests (subprocess: the benches need >1
virtual device).

The key guard: ``bench_a2a``'s ``a2a_combine`` rows must time the
inverse path on a *dispatched* tensor — the capacity-grouped
(E_global, W*cap, d) shape — not on the raw dispatch input (the PR-3
fix; a regression would silently re-time the forward path).

``bench_boundary`` pin: the fused rs->ag chain must (a) drop the
back-to-back unfused pair's two mid-chain barrier rendezvous (the rs
exit + ag entry flush) — an exact event-count fact of the
``push_rs_ring_ag`` protocol — and
(b) report higher measured ``overlap_eff`` on its traced kernel row
than the pair's at the same shape."""
import textwrap

import pytest

from conftest import run_devices

A2A_SCRIPT = textwrap.dedent("""
    import numpy as np
    import jax
    from benchmarks import bench_a2a

    w = min(8, jax.device_count())
    calls = []

    def fake_time_fn(fn, *args, **kw):
        calls.append(tuple(np.asarray(a).shape for a in args))
        jax.block_until_ready(fn(*args))  # still execute once: shapes real
        return 1.0

    bench_a2a.time_fn = fake_time_fn
    names = [line.split(",")[0] for line in bench_a2a.rows()]
    assert len(calls) == len(names), (len(calls), len(names))
    n_combine = 0
    for name, shapes in zip(names, calls):
        shape_tag = name.split("/")[1]            # e.g. "E16c32d128"
        e_glob, rest = shape_tag[1:].split("c")
        cap, d = rest.split("d")
        e_glob, cap, d = int(e_glob), int(cap), int(d)
        if name.startswith("a2a_dispatch"):
            assert shapes[0] == (w * e_glob, cap, d), (name, shapes)
        else:
            assert name.startswith("a2a_combine"), name
            # the inverse is timed on the DISPATCHED tensor: the
            # capacity-grouped (E_global, W*cap, d) global shape
            assert shapes[0] == (e_glob, w * cap, d), (name, shapes)
            n_combine += 1
    assert n_combine >= 3, names
    # the backend axis is present: at least one kernel-lowered row pair
    assert any(n.endswith("/kernel") for n in names), names
    print("OK bench_a2a", n_combine)
""")


@pytest.mark.parametrize("devices", [4])
def test_bench_a2a_combine_times_dispatched_tensor(devices):
    out = run_devices(A2A_SCRIPT, devices=devices, timeout=900)
    assert "OK bench_a2a" in out


BOUNDARY_SCRIPT = textwrap.dedent("""
    # The fused-boundary acceptance, pinned against the real bench path:
    #   (a) DETERMINISTIC: one kernel call of the chained push_rs_ring_ag
    #       protocol records exactly TWO barrier rendezvous (2*world
    #       events) fewer than the back-to-back push_rs + ring_ag pair —
    #       the pair's rs-exit + ag-entry flush is gone from the event
    #       stream itself (entry/exit of the one chained context remain).
    #   (b) MEASURED: bench_boundary's traced kernel rows report higher
    #       overlap_eff for fused than for the unfused pair at the same
    #       shape — the dropped mid-stream rendezvous count as exposed
    #       comm in the obs reduction (only a PE's first barrier per
    #       kernel instance is launch skew), so the pair pays strictly
    #       more exposed time by construction. CPU wall-clock is still
    #       noisy, so each attempt is a full PAIRED re-measurement and
    #       the assert allows a bounded number of retries.
    import functools, os
    os.environ["_REPRO_BENCH_TRACE"] = "1"  # time_fn: measured fields on
    from repro import obs
    obs.enable()  # BEFORE first compile: executor spans are trace-gated
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro import ops
    from repro.core import collective_matmul as cm
    from benchmarks import bench_boundary

    w = min(8, jax.device_count())
    mesh = jax.make_mesh((w,), ("tp",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    rng = np.random.RandomState(0)
    m, k, n, f = bench_boundary.SHAPES[0]
    y = jnp.asarray(rng.randn(m, k), jnp.float32)
    wo = jnp.asarray(rng.randn(k, n), jnp.float32)
    wi = jnp.asarray(rng.randn(n, f), jnp.float32)
    xr = jnp.asarray(rng.randn(m, n), jnp.float32)

    fu = cm.make_sharded(
        functools.partial(bench_boundary._unfused, backend="kernel"),
        mesh, *bench_boundary.SPECS)
    ff = cm.make_sharded(
        functools.partial(ops.matmul_rs_ag_matmul, axis="tp", mode="ring",
                          backend="kernel", out_dtype=jnp.float32,
                          mid=bench_boundary._mid),
        mesh, *bench_boundary.SPECS)

    def barrier_events(fn):
        jax.block_until_ready(fn(y, wo, wi, xr))  # warmup/compile
        obs.clear()
        jax.block_until_ready(fn(y, wo, wi, xr))
        ev = obs.events(clear=True)
        assert ev, "no trace events — kernel backend not engaged?"
        return sum(1 for e in ev if e.kind == "barrier")

    nb_u = barrier_events(fu)
    nb_f = barrier_events(ff)
    assert nb_f == nb_u - 2 * w, (nb_u, nb_f, w)

    bench_boundary.SHAPES = bench_boundary.SHAPES[:1]  # kernel shape only
    KU = f"boundary/{m}x{k}x{n}x{f}/unfused_pair/ring/kernel"
    KF = f"boundary/{m}x{k}x{n}x{f}/fused/ring/kernel"
    for attempt in range(3):
        eff = {}
        for line in bench_boundary.rows():
            parts = line.split(",")
            for p in parts[2:]:
                key, sep, v = p.partition("=")
                if sep and key == "overlap_eff":
                    eff[parts[0]] = float(v)
        assert KU in eff and KF in eff, sorted(eff)
        if eff[KF] > eff[KU]:
            break
    assert eff[KF] > eff[KU], eff
    print("OK boundary", nb_u, nb_f, eff[KU], eff[KF])
""")


def test_bench_boundary_fused_beats_unfused_pair_overlap_eff():
    out = run_devices(BOUNDARY_SCRIPT, devices=8, timeout=1200)
    assert "OK boundary" in out


def test_parse_row_measured_fields():
    """Trailing k=v fields (--trace runs) land under 'measured'; plain
    rows stay unchanged (no 'measured' key)."""
    from benchmarks.run import _mode_vocabulary, parse_row

    modes = _mode_vocabulary()
    plain = parse_row("fig11_13", "ag_gemm/256x512x512/ring/kernel,123.4,1.0",
                      8, modes)
    assert plain is not None and "measured" not in plain
    traced = parse_row(
        "fig11_13",
        "ag_gemm/256x512x512/ring/kernel,123.4,1.0,"
        "overlap_eff=0.71,stall_frac=0.29",
        8, modes)
    assert traced["measured"] == {"overlap_eff": 0.71, "stall_frac": 0.29}
    assert traced["us_per_call"] == 123.4
    assert traced["policy"]["mode"] == "ring"
    # unknown trailing fields are ignored, not crashed on
    odd = parse_row("t", "op/1x1/ring,5.0,d,bogus=1,alsobogus", 8, modes)
    assert odd is not None and "measured" not in odd


def test_check_regressions_tolerates_measured_fields(tmp_path):
    """An old baseline (no measured fields) must compare cleanly against
    a fresh traced run whose records carry them."""
    import json

    from benchmarks.run import check_regressions

    base = [{"name": "t/op/ring", "us_per_call": 1000.0}]
    fresh = [{"name": "t/op/ring", "us_per_call": 1050.0,
              "measured": {"overlap_eff": 0.8, "stall_frac": 0.2}}]
    bp, fp = tmp_path / "base.json", tmp_path / "fresh.json"
    bp.write_text(json.dumps(base))
    fp.write_text(json.dumps(fresh))
    assert check_regressions(str(bp), str(fp), tolerance=1.0) == 0


def test_bench_row_appends_measured_fields(monkeypatch):
    """common.row appends LAST_MEASURED as k=v; cleared when empty."""
    from benchmarks import common

    monkeypatch.setattr(common, "LAST_MEASURED",
                        {"overlap_eff": 0.5, "stall_frac": 0.5})
    line = common.row("op/shape/ring", 12.0, "1.23")
    assert line == "op/shape/ring,12.0,1.23,overlap_eff=0.5,stall_frac=0.5"
    monkeypatch.setattr(common, "LAST_MEASURED", {})
    assert common.row("op/shape/ring", 12.0, "1.23") == "op/shape/ring,12.0,1.23"
