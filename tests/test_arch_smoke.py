"""Per-architecture smoke tests (REQUIRED): a reduced same-family config
runs one forward/train step on CPU (one device, (1,1) mesh), asserting
output shapes + no NaNs. Decode smoke included."""
import os
import sys
sys.path.insert(0, os.path.dirname(__file__))

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS, reduced
from repro.configs.base import ParallelConfig, TrainConfig
from repro.models import build_model
from repro.train import optimizer as opt_mod
from repro.train.train_step import TrainStepOut, make_train_step

PCFG = ParallelConfig(dp=1, tp=1, fsdp=False, compute_dtype="float32",
                      param_dtype="float32", overlap_mode="none")


def _extra(cfg, model, b):
    if cfg.family == "vlm":
        return {"vision": jnp.ones((b, cfg.vision_tokens, cfg.vision_dim), jnp.float32)}, \
               {"vision": P(None, None, None)}
    if cfg.family == "whisper":
        return {"frames": jnp.ones((b, model.frames_padded, cfg.d_model), jnp.float32)}, \
               {"frames": P(None, None, None)}
    return None, None


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_forward_loss(arch, one_device_mesh):
    cfg = reduced(ARCHS[arch])
    model = build_model(cfg, PCFG)
    params, pspecs = model.init(jax.random.PRNGKey(0), jnp.float32)
    b, s = 2, 16
    tokens = jnp.asarray(np.random.RandomState(0).randint(0, cfg.vocab_size, (b, s)),
                         jnp.int32)
    extra, espec = _extra(cfg, model, b)
    f = jax.jit(jax.shard_map(
        lambda p, t, l, e: model.loss_local(p, t, l, e),
        mesh=one_device_mesh,
        in_specs=(pspecs, P(None, None), P(None, None), espec),
        out_specs=P(), check_vma=False))
    loss = f(params, tokens, tokens, extra)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), arch
    # loss should be near ln(vocab) at init (within a generous band)
    assert 0.5 * np.log(cfg.vocab_size) < float(loss) < 3.0 * np.log(cfg.vocab_size)


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_train_step(arch, one_device_mesh):
    cfg = reduced(ARCHS[arch])
    model = build_model(cfg, PCFG)
    params, pspecs = model.init(jax.random.PRNGKey(0), jnp.float32)
    if cfg.family == "whisper":
        spec_tree = {"top": model.top_specs, "encoder": model.enc_specs,
                     "layers": model.dec_specs}
    else:
        spec_tree = {"top": model.top_specs, "layers": model.layer_specs}
    tcfg = TrainConfig(learning_rate=1e-3, warmup_steps=1, total_steps=10)
    step = make_train_step(model, tcfg, PCFG, spec_tree)
    opt = opt_mod.init_opt_state(params, jnp.float32)
    b, s = 2, 16
    tokens = jnp.asarray(np.random.RandomState(1).randint(0, cfg.vocab_size, (b, s)),
                         jnp.int32)
    extra, espec = _extra(cfg, model, b)
    opt_specs = opt_mod.OptState(P(), pspecs, pspecs)

    f = jax.jit(jax.shard_map(
        lambda p, o, t, l, e: step(p, o, None, t, l, e),
        mesh=one_device_mesh,
        in_specs=(pspecs, opt_specs, P(None, None), P(None, None), espec),
        out_specs=(pspecs, opt_specs, None, TrainStepOut(P(), P(), P())),
        check_vma=False))
    new_params, new_opt, _, metrics = f(params, opt, tokens, tokens, extra)
    assert np.isfinite(float(metrics.loss))
    assert np.isfinite(float(metrics.grad_norm)) and float(metrics.grad_norm) > 0
    # params actually moved
    moved = any(
        float(jnp.max(jnp.abs(a - b))) > 0
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(new_params))
    )
    assert moved, arch
    # shapes preserved
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(new_params)):
        assert a.shape == b.shape and a.dtype == b.dtype


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_decode_step(arch, one_device_mesh):
    cfg = reduced(ARCHS[arch])
    model = build_model(cfg, PCFG)
    params, pspecs = model.init(jax.random.PRNGKey(0), jnp.float32)
    b, s_max = 2, 32
    caches = jax.tree.map(lambda sh: jnp.zeros(sh.shape, sh.dtype),
                          model.cache_shapes(b, s_max, jnp.float32))
    cache_specs = jax.tree.map(lambda x: P(*([None] * x.ndim)), caches)
    tok = jnp.ones((b, 1), jnp.int32)
    f = jax.jit(jax.shard_map(
        lambda p, c, t: model.decode_step_local(p, c, jnp.int32(3), t),
        mesh=one_device_mesh,
        in_specs=(pspecs, cache_specs, P(None, None)),
        out_specs=(P(None, None), cache_specs), check_vma=False))
    logits, new_caches = f(params, caches, tok)
    assert logits.shape == (b, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits))), arch
    # caches updated (same structure)
    assert jax.tree.structure(caches) == jax.tree.structure(new_caches)
